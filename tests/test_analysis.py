"""repro.analysis (PR 6): invariant linter, runtime contracts, retrace tracer.

Three layers, three test groups:

* linter: every JF rule fires on a minimal bad fixture and stays silent on
  the corrected twin; the tree at HEAD lints clean (CI's lint lane in test
  form).
* contracts: each structural corruption of a PathSystem / PathSystemBatch /
  SimResult trips the matching check with a message naming the offending
  index, and the real builders (jellyfish / fat-tree / Clos / SWDC) plus a
  delta chain pass with checks forced on — no false positives.
* retrace: re-running a solved workload compiles nothing (the
  one-compile-per-shape-bucket guarantee), and the compile counter itself
  is live.
"""

import dataclasses
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro import env
from repro.analysis import (
    ContractViolation,
    RULES,
    check_path_system,
    check_path_system_batch,
    check_sim_state,
    lint_paths,
    lint_source,
    set_check_enabled,
)
from repro.core import (
    ClosSpec,
    build_clos,
    build_path_system,
    fail_links,
    fattree,
    jellyfish,
    random_permutation_traffic,
    swdc_ring,
    update_path_system,
)
from repro.core.flow import PathSystemBatch
from repro.sim import SimConfig, simulate, steady_poisson

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture
def checks_on():
    prev = set_check_enabled(True)
    try:
        yield
    finally:
        set_check_enabled(prev)


# --------------------------------------------------------------------------- #
# linter: rule fixtures
# --------------------------------------------------------------------------- #

# (rule, path-the-snippet-pretends-to-live-at, bad source, good source)
_RULE_FIXTURES = [
    (
        "JF001",
        "src/repro/core/routing.py",
        "order = hash((u, v))\n",
        "from .metrics import mix\norder = mix(u, v)\n",
    ),
    (
        "JF001",
        "src/repro/sim/ecmp.py",
        "seen = {1, 2}\nfor e in seen:\n    go(e)\n",
        "seen = {1, 2}\nfor e in sorted(seen):\n    go(e)\n",
    ),
    (
        "JF001",
        "src/repro/core/flow.py",
        "edges = set()\nrows = list(edges)\n",
        "edges = set()\nrows = sorted(edges)\n",
    ),
    (
        "JF002",
        "src/repro/core/routing.py",
        "import numpy as np\norder = np.argsort(keys)\n",
        'import numpy as np\norder = np.argsort(keys, kind="stable")\n',
    ),
    (
        "JF003",
        "src/repro/core/anywhere.py",
        'import os\nv = int(os.environ.get("REPRO_FOO", "1"))\n',
        'from repro import env\nv = env.read("REPRO_FOO")\n',
    ),
    (
        "JF003",
        "benchmarks/some_bench.py",
        'import os\nv = os.getenv("REPRO_BENCH_OUT")\n',
        'from repro import env\nv = env.read("REPRO_BENCH_OUT")\n',
    ),
    (
        "JF004",
        "src/repro/kernels/newkernel.py",
        (
            "def run(a, b):\n"
            "    a = jnp.pad(a, ((0, 4), (0, 0)))\n"
            "    return pl.pallas_call(_kernel, out_shape=sh)(a, b)\n"
        ),
        (
            "def run(a, b):\n"
            "    a, b = check_run_dtype(a, b)\n"
            "    a = jnp.pad(a, ((0, 4), (0, 0)))\n"
            "    return pl.pallas_call(_kernel, out_shape=sh)(a, b)\n"
        ),
    ),
    (
        "JF005",
        "src/repro/sim/engine.py",
        "total = jnp.sum(loads)\n",
        "total = _fold_sum(loads)\n",
    ),
    (
        "JF005",
        "src/repro/core/flow.py",
        'y = jnp.einsum("ps,p->s", inc, rates)\n',
        "y = _ordered_fan_in_sum(fr, table)\n",
    ),
    (
        "JF006",
        "src/repro/core/flow.py",
        (
            "def make_step(n_steps):\n"
            "    @jax.jit\n"
            "    def step(x):\n"
            "        return x * n_steps\n"
            "    return step\n"
        ),
        (
            '@functools.partial(jax.jit, static_argnames=("n_steps",))\n'
            "def step(x, n_steps):\n"
            "    return x * n_steps\n"
        ),
    ),
    (
        "JF006",
        "src/repro/sim/engine.py",
        "def warm(cfg):\n    return jax.jit(lambda x: x * cfg.dt)\n",
        "@jax.jit\ndef warm_step(x, dt):\n    return x * dt\n",
    ),
    (
        "JF000",
        "src/repro/core/flow.py",
        "x = 1  # repro-lint: disable=JF999\n",
        "x = 1  # repro-lint: disable=JF005\n",
    ),
    (
        "JF000",
        "src/repro/sim/engine.py",
        # comma lists are validated per id; IR rule ids (JF100-JF105) are
        # legitimate pragma targets even though the AST linter never fires
        # them itself
        "y = 2  # repro-lint: disable=JF005,JF01\n",
        "y = 2  # repro-lint: disable=JF005,JF104\n",
    ),
]


@pytest.mark.parametrize(
    "rule,path,bad,good",
    _RULE_FIXTURES,
    ids=[f"{r}-{i}" for i, (r, *_) in enumerate(_RULE_FIXTURES)],
)
def test_rule_fires_and_silences(rule, path, bad, good):
    fired = lint_source(bad, path)
    assert [v.rule for v in fired] == [rule]
    # the message is actionable: it names the rule and reads as guidance
    assert fired[0].line >= 1
    assert len(fired[0].message) > 20
    assert lint_source(good, path) == []


def test_rules_are_scoped():
    # JF001/JF002 only bind in routing/sim modules; JF005 only in the
    # solver files with a padded reduction axis; JF006 exempts the one-shot
    # launch drivers.  Out-of-scope twins of firing fixtures stay silent.
    assert lint_source("x = hash(y)\n", "src/repro/core/topology.py") == []
    assert (
        lint_source("import numpy as np\no = np.argsort(k)\n",
                    "src/repro/core/metrics.py")
        == []
    )
    assert lint_source("y = jnp.sum(x)\n", "src/repro/core/routing.py") == []
    assert (
        lint_source("def main():\n    f = jax.jit(lambda x: x)\n",
                    "src/repro/launch/serve.py")
        == []
    )


def test_pragma_suppresses():
    src = 'import numpy as np\no = np.argsort(k)  # repro-lint: disable=JF002\n'
    assert lint_source(src, "src/repro/core/routing.py") == []


def test_pragma_with_unknown_id_does_not_suppress():
    # a typo'd pragma must not silently disarm the rule it meant to name:
    # the original violation still fires, plus JF000 for the bad id
    src = 'import numpy as np\no = np.argsort(k)  # repro-lint: disable=JF02\n'
    rules = sorted(v.rule for v in lint_source(src, "src/repro/core/routing.py"))
    assert rules == ["JF000", "JF002"]
    # and JF000 cannot suppress itself
    src = "x = 1  # repro-lint: disable=JF999,JF000\n"
    assert [v.rule for v in lint_source(src, "src/repro/core/flow.py")] == [
        "JF000"
    ]


def test_tree_lints_clean_at_head():
    violations = lint_paths([str(ROOT / "src"), str(ROOT / "benchmarks")])
    assert violations == [], "\n".join(str(v) for v in violations)


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "src" / "repro" / "core" / "routing.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import numpy as np\norder = np.argsort(keys)\n")
    code = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(tmp_path)],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": str(ROOT / "src")},
    )
    assert code.returncode == 1
    assert "JF002" in code.stdout
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(ROOT / "benchmarks")],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": str(ROOT / "src")},
    )
    assert ok.returncode == 0, ok.stdout + ok.stderr


def test_every_rule_has_a_fixture():
    assert {r for r, *_ in _RULE_FIXTURES} == set(RULES)


# --------------------------------------------------------------------------- #
# env registry
# --------------------------------------------------------------------------- #


def test_env_rejects_bad_values(monkeypatch):
    monkeypatch.setenv("REPRO_LP_PATH_LIMIT", "twenty")
    with pytest.raises(ValueError, match="REPRO_LP_PATH_LIMIT"):
        env.read("REPRO_LP_PATH_LIMIT")
    monkeypatch.setenv("REPRO_ROUTE_TILE_BYTES", "12")  # below 1 MiB floor
    with pytest.raises(ValueError, match="REPRO_ROUTE_TILE_BYTES"):
        env.read("REPRO_ROUTE_TILE_BYTES")
    monkeypatch.setenv("REPRO_APSP_BACKEND", "quantum")
    with pytest.raises(ValueError, match="REPRO_APSP_BACKEND"):
        env.read("REPRO_APSP_BACKEND")


def test_env_defaults_and_is_set(monkeypatch):
    monkeypatch.delenv("REPRO_LP_PATH_LIMIT", raising=False)
    assert env.read("REPRO_LP_PATH_LIMIT") == 20000
    assert not env.is_set("REPRO_LP_PATH_LIMIT")
    monkeypatch.setenv("REPRO_LP_PATH_LIMIT", "12345")
    assert env.read("REPRO_LP_PATH_LIMIT") == 12345
    assert env.is_set("REPRO_LP_PATH_LIMIT")
    with pytest.raises(KeyError):
        env.read("REPRO_NOT_A_REGISTERED_KNOB")


def test_env_validates_whole_registry_at_import(monkeypatch):
    # any repro import validates EVERY registered variable, so a typo'd
    # setting fails at startup instead of being read mid-sweep
    r = subprocess.run(
        [sys.executable, "-c", "import repro.env"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": str(ROOT / "src"),
             "REPRO_SIM_MAX_STEPS": "0"},
    )
    assert r.returncode != 0
    assert "REPRO_SIM_MAX_STEPS" in r.stderr


# --------------------------------------------------------------------------- #
# contracts: corruptions fire, real builders pass
# --------------------------------------------------------------------------- #


def _small_ps():
    top = jellyfish(24, 8, 4, seed=3)
    comm = random_permutation_traffic(top, seed=4)
    return top, comm, build_path_system(top, comm, k=4)


def test_contract_out_of_range_slot(checks_on):
    _, _, ps = _small_ps()
    pe = ps.path_edges.copy()
    row = int(np.argmax(ps.path_len >= 1))
    pe[row, 0] = ps.n_slots + 7  # beyond even the padding sentinel
    bad = dataclasses.replace(ps, path_edges=pe)
    with pytest.raises(ContractViolation, match="directed slot"):
        check_path_system(bad)


def test_contract_wrong_padding_sentinel(checks_on):
    _, _, ps = _small_ps()
    lens = np.asarray(ps.path_len)
    rows = np.flatnonzero(lens < ps.path_edges.shape[1])
    assert rows.size, "need a row with padded columns"
    pe = ps.path_edges.copy()
    pe[rows[0], lens[rows[0]]] = 0  # valid slot id where the sentinel belongs
    bad = dataclasses.replace(ps, path_edges=pe)
    with pytest.raises(ContractViolation, match="beyond"):
        check_path_system(bad)


def test_contract_nonpositive_capacity(checks_on):
    _, _, ps = _small_ps()
    caps = ps.capacities.copy()
    caps[0] = 0.0
    bad = dataclasses.replace(ps, capacities=caps)
    with pytest.raises(ContractViolation, match="positive and finite"):
        check_path_system(bad)


def test_contract_broken_row_map(checks_on):
    top, comm, ps = _small_ps()
    cut = fail_links(top, n_links=2, seed=5)
    ps2 = update_path_system(ps, top, cut, comm)
    assert ps2.row_map is not None
    rm = ps2.row_map.copy()
    kept = np.flatnonzero(rm >= 0)
    assert kept.size >= 2, "delta must preserve some rows"
    rm[kept[1]] = rm[kept[0]]  # two rows claim one predecessor
    bad = dataclasses.replace(ps2, row_map=rm)
    with pytest.raises(ContractViolation, match="injectiv"):
        check_path_system(bad)


def test_contract_batch_finite_capacity_in_padded_slot(checks_on):
    systems = []
    for s in range(2):
        top = jellyfish(20 + 8 * s, 8, 4, seed=s)
        comm = random_permutation_traffic(top, seed=s + 7)
        systems.append(build_path_system(top, comm, k=4))
    batch = PathSystemBatch.from_systems(systems)
    pad = ~np.asarray(batch.slot_valid)
    assert pad.any(), "batch must have padded slots for this corruption"
    inv = batch.inv_cap.copy()
    i, s = np.argwhere(pad)[0]
    inv[i, s] = 0.5  # a finite capacity leaked into the padding
    bad = dataclasses.replace(batch, inv_cap=inv)
    with pytest.raises(ContractViolation, match="infinite capacity"):
        check_path_system_batch(bad)


def test_contract_batch_padded_row_owner(checks_on):
    systems = []
    for s in range(2):
        top = jellyfish(20 + 8 * s, 8, 4, seed=s)
        comm = random_permutation_traffic(top, seed=s + 7)
        systems.append(build_path_system(top, comm, k=4))
    batch = PathSystemBatch.from_systems(systems)
    n0 = int(batch.n_paths[0])
    assert n0 < batch.p_max, "instance 0 must have padded rows"
    owner = batch.path_owner.copy()
    owner[0, n0] = 0  # padded row stealing a real commodity
    bad = dataclasses.replace(batch, path_owner=owner)
    with pytest.raises(ContractViolation, match="padded row"):
        check_path_system_batch(bad)


def test_contract_sim_result_fires(checks_on):
    top = jellyfish(24, 8, 4, seed=1)
    comm = random_permutation_traffic(top, seed=2)
    ps = build_path_system(top, comm, k=4)
    wl = steady_poisson(10, rate=3.0, size=8.0)
    cfg = SimConfig(max_flows=128, max_arrivals=4, wf_iters=4)
    res = simulate([ps], wl, policy="ecmp", config=cfg, seed=0)
    thr = np.asarray(res.throughput).copy()
    thr[0, 0] = -1.0
    bad = dataclasses.replace(res, throughput=thr)
    with pytest.raises(ContractViolation, match="throughput"):
        check_sim_state(bad)


def test_contracts_pass_on_real_builders(checks_on):
    # check_path_system runs INSIDE build_path_system when enabled; these
    # must construct without a ContractViolation across topology families
    tops = [
        jellyfish(30, 10, 6, seed=0),
        fattree(4),
        build_clos(ClosSpec(n_leaves=4, servers_per_leaf=4,
                            uplinks_per_leaf=4, n_spines=4, spine_ports=4)),
        swdc_ring(24, 8, seed=0, degree=4),
    ]
    for top in tops:
        comm = random_permutation_traffic(top, seed=1)
        ps = build_path_system(top, comm, k=4)
        check_path_system(ps, top, name=f"recheck[{top.name}]")


def test_contracts_pass_on_delta_chain(checks_on):
    # update_path_system validates its spliced output when enabled; a
    # fail + heal chain must stay contract-clean end to end
    top, comm, ps = _small_ps()
    cut = fail_links(top, n_links=2, seed=11)
    ps_cut = update_path_system(ps, top, cut, comm)
    ps_back = update_path_system(ps_cut, cut, top, comm)
    check_path_system(ps_back, top, name="delta-heal")


def test_argsort_regression_hashseed_independent():
    # Satellite of PR 6: the slot-lookup argsort at routing's enumerator
    # boundary was unstable (numpy introsort over equal keys).  The path
    # table must be byte-identical across Python hash seeds.
    prog = (
        "import hashlib, numpy as np\n"
        "from repro.core import build_path_system, jellyfish, "
        "random_permutation_traffic\n"
        "top = jellyfish(24, 8, 4, seed=3)\n"
        "comm = random_permutation_traffic(top, seed=4)\n"
        "ps = build_path_system(top, comm, k=4)\n"
        "h = hashlib.sha256()\n"
        "for a in (ps.path_edges, ps.path_len, ps.path_owner):\n"
        "    h.update(np.ascontiguousarray(a).tobytes())\n"
        "print(h.hexdigest())\n"
    )
    digests = []
    for seed in ("0", "424242"):
        r = subprocess.run(
            [sys.executable, "-c", prog],
            capture_output=True, text=True,
            env={**os.environ, "PYTHONPATH": str(ROOT / "src"),
                 "PYTHONHASHSEED": seed},
        )
        assert r.returncode == 0, r.stderr
        digests.append(r.stdout.strip())
    assert digests[0] == digests[1]


# --------------------------------------------------------------------------- #
# retrace tracer
# --------------------------------------------------------------------------- #


def test_counter_sees_fresh_compiles():
    import jax
    import jax.numpy as jnp

    from repro.analysis.retrace import track_compiles

    with track_compiles() as c:
        fresh = jax.jit(lambda x: x * 2 + 1)
        fresh(jnp.arange(7.0)).block_until_ready()
    assert c.count >= 1
    assert all("backend_compile" in e for e in c.events)


def test_solver_recompiles_nothing_within_a_bucket():
    from repro.analysis.retrace import solver_cache_sizes, track_compiles
    from repro.core import mw_concurrent_flow_batch

    def batch_of(seeds):
        out = []
        for s in seeds:
            top = jellyfish(22 + 2 * (s % 2), 8, 4, seed=s)
            comm = random_permutation_traffic(top, seed=s + 5)
            out.append(build_path_system(top, comm, k=4))
        return out

    mw_concurrent_flow_batch(batch_of([0, 1]), iters=24)  # warm the bucket
    before = solver_cache_sizes()
    with track_compiles() as c:
        mw_concurrent_flow_batch(batch_of([2, 3]), iters=24)
    after = solver_cache_sizes()
    assert c.count == 0, f"retrace within a shape bucket: {c.events}"
    assert after == before
