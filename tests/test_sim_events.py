"""Tests for ``repro.sim.events`` — live fault injection (paper §4.3).

The three contracts of the segmented driver:

* **CT-segment parity** — an empty schedule (even with forced segment
  splits) is bit-identical to one unsegmented ``simulate`` call, every
  ``SimResult`` field included;
* **volume conservation** — across a fail -> heal -> expand chain,
  offered == delivered + blackholed + in-flight per instance, with
  migration records that account every disrupted flow;
* **carry-migration integrity** — surviving flows keep their state
  bit-exactly through an injective row map (``check_carry_migration``
  rejects forged migrations).

Plus the producers' validation surfaces (``fail_links`` / ``heal_links``
parameter checks, schedule validation, ``REPRO_SIM_EVENT_*`` import-time
validation) and the MTBF/MTTR schedule generator's determinism.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.analysis.contracts import ContractViolation, check_carry_migration
from repro.core import build_path_system, jellyfish
from repro.core.failures import fail_links, fail_switches, heal_links
from repro.core.routing import update_path_system
from repro.core.topology import edge_fingerprint
from repro.core.traffic import (
    permutation_commodities,
    random_server_permutation,
)
from repro.sim import (
    Event,
    SimConfig,
    event_summary,
    poisson_failure_schedule,
    simulate,
    simulate_events,
    steady_poisson,
    validate_schedule,
)
from repro.core.flow import PathSystemBatch

_SIM_FIELDS = (
    "throughput", "active", "fct_hist", "fct_sum", "fct_count",
    "comm_delivered", "comm_offered", "util_sum", "drops", "admitted",
    "blackholed", "blackholed_total", "inflight", "demands", "slot_valid",
)


def _instances(n=2, n_sw=20, ports=8, net=5):
    tops = [jellyfish(n_sw, ports, net, seed=s + 1) for s in range(n)]
    comms = [
        permutation_commodities(
            t, random_server_permutation(t.n_servers, np.random.default_rng(s))
        )
        for s, t in enumerate(tops)
    ]
    return tops, comms


def _cfg():
    return SimConfig(max_flows=256, max_arrivals=8, wf_iters=6)


def _assert_conserved(res):
    off = res.comm_offered.sum(axis=1, dtype=np.float64)
    dele = res.comm_delivered.sum(axis=1, dtype=np.float64)
    err = np.abs(off - (dele + res.blackholed_total + res.inflight))
    assert np.all(err <= 1e-3 * np.maximum(off, 1.0)), err


# --------------------------------------------------------------------------- #
# CT-segment parity
# --------------------------------------------------------------------------- #


def test_empty_schedule_bit_parity():
    tops, comms = _instances()
    systems = [build_path_system(t, c, k=4) for t, c in zip(tops, comms)]
    wl = steady_poisson(32, 3.0)
    base = simulate(
        PathSystemBatch.from_systems(list(systems)), wl, policy="ecmp",
        config=_cfg(), seed=7,
    )
    ev = simulate_events(
        tops, comms, [], wl, systems=list(systems), policy="ecmp",
        config=_cfg(), seed=7,
    )
    for f in _SIM_FIELDS:
        a = np.asarray(getattr(base, f))
        b = np.asarray(getattr(ev.result, f))
        assert a.shape == b.shape and np.array_equal(a, b), f
    assert ev.events == []
    assert ev.boundaries == [0]


def test_forced_split_bit_parity():
    # REPRO_SIM_EVENT_MAX_SEG-style chunking with no events must pass the
    # device carry through untouched: same bits as one unsegmented scan.
    tops, comms = _instances()
    systems = [build_path_system(t, c, k=4) for t, c in zip(tops, comms)]
    wl = steady_poisson(32, 3.0)
    base = simulate(
        PathSystemBatch.from_systems(list(systems)), wl, policy="ecmp",
        config=_cfg(), seed=7,
    )
    ev = simulate_events(
        tops, comms, [], wl, systems=list(systems), policy="ecmp",
        config=_cfg(), seed=7, max_seg=10,
    )
    assert ev.boundaries == [0, 10, 20, 30]
    for f in _SIM_FIELDS:
        a = np.asarray(getattr(base, f))
        b = np.asarray(getattr(ev.result, f))
        assert a.shape == b.shape and np.array_equal(a, b), f


# --------------------------------------------------------------------------- #
# conservation + migration across live events
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("policy", ["ecmp", "ksp_lc", "mptcp"])
def test_fail_heal_expand_conservation(policy):
    tops, comms = _instances()
    wl = steady_poisson(40, 3.0)
    sched = [
        Event(step=12, kind="fail_links", n_links=4, seed=5, tag="f"),
        Event(step=22, kind="heal_links", heal_of="f"),
        Event(step=30, kind="expand", grow=1, seed=6),
    ]
    ev = simulate_events(
        tops, comms, sched, wl, k=4, policy=policy, config=_cfg(), seed=7,
    )
    _assert_conserved(ev.result)
    assert [r["step"] for r in ev.events] == [12, 22, 30]
    B = len(tops)
    for rec in ev.events:
        # every previously-live flow is accounted: survived + disrupted
        assert rec["disrupted"].shape == (B,)
        assert np.all(rec["survived"] >= 0)
        assert np.all(
            rec["disrupted"] == rec["reselected"] + rec["killed"]
        )
    # detection lag blackholes some traffic at the failure
    assert np.all(ev.result.blackholed_total >= 0)
    assert ev.result.blackholed_total.sum() > 0
    # final topologies carry the expansion
    assert all(t.n_switches == 21 for t in ev.tops)
    summ = event_summary(ev)
    assert len(summ) == 3
    assert summ[0]["kinds"] == ["fail_links"]
    assert np.all(np.isfinite(summ[0]["throughput_retention"]))
    assert np.all(summ[0]["blackholed_bytes"] >= 0)


def test_lag_zero_blackholes_nothing_on_survivable_failure():
    # With lag=0 a disrupted flow re-selects immediately; blackholed volume
    # can only come from killed commodities, so on a mild failure where
    # every commodity keeps a route nothing is blackholed.
    tops, comms = _instances()
    wl = steady_poisson(30, 3.0)
    sched = [Event(step=10, kind="fail_links", n_links=2, seed=3)]
    ev = simulate_events(
        tops, comms, sched, wl, k=4, policy="ecmp", config=_cfg(), seed=7,
        lag=0,
    )
    _assert_conserved(ev.result)
    if all(int(r["killed"].sum()) == 0 for r in ev.events):
        assert np.all(ev.result.blackholed_total == 0.0)
    ev_lag = simulate_events(
        tops, comms, sched, wl, k=4, policy="ecmp", config=_cfg(), seed=7,
        lag=4,
    )
    _assert_conserved(ev_lag.result)
    assert ev_lag.result.blackholed_total.sum() >= \
        ev.result.blackholed_total.sum()


def test_heal_inverts_fail_delta():
    top = jellyfish(20, 8, 5, seed=3)
    failed = fail_links(top, seed=11, n_links=4)
    healed = heal_links(failed, failed.meta["edges_removed"])
    assert edge_fingerprint(healed) == edge_fingerprint(top)
    assert healed.meta["delta_kind"] == "heal_links"
    assert healed.meta["edges_removed"] == []
    assert sorted(healed.meta["edges_added"]) == sorted(
        failed.meta["edges_removed"]
    )
    # the pure-addition delta certifies through update_path_system
    comm = permutation_commodities(
        top, random_server_permutation(top.n_servers, np.random.default_rng(0))
    )
    ps0 = build_path_system(top, comm, k=4)
    ps1 = update_path_system(ps0, top, failed, comm)
    ps2 = update_path_system(ps1, failed, healed, comm)
    ref = build_path_system(healed, comm, k=4, cache=False)
    assert ps2.n_paths == ref.n_paths
    assert np.array_equal(
        np.sort(np.asarray(ps2.path_len)), np.sort(np.asarray(ref.path_len))
    )


# --------------------------------------------------------------------------- #
# carry-migration contract
# --------------------------------------------------------------------------- #


def _migration_fixture():
    # one instance, 3 old rows -> 3 new rows; rows 0,2 survive, row 1 dies
    row_o = np.array([[0, 1, 2, 4]], np.int32)  # slot 3 empty (p_old=4)
    rem_o = np.array([[3.0, 2.0, 1.0, 0.0]], np.float32)
    age_o = np.array([[5.0, 4.0, 3.0, 0.0]], np.float32)
    fid_o = np.array([[7, 8, 9, 0]], np.uint32)
    hold_o = np.zeros((1, 4), np.int32)
    fwd = [np.array([1, -1, 0], np.int64)]
    row_n = np.array([[1, 2, 0, 3]], np.int32)  # slot 1 re-selected (p_new=3)
    rem_n = rem_o.copy()
    age_n = age_o.copy()
    fid_n = fid_o.copy()
    hold_n = np.array([[0, 2, 0, 0]], np.int32)
    return (row_o, row_n, rem_o, rem_n, age_o, age_n, fid_o, fid_n,
            hold_o, hold_n, fwd)


def test_carry_migration_contract_accepts_valid():
    args = _migration_fixture()
    check_carry_migration(*args, 4, 3, 2)


def test_carry_migration_rejects_noninjective_map():
    args = list(_migration_fixture())
    args[10] = [np.array([1, 1, 0], np.int64)]  # two old rows -> new row 1
    with pytest.raises(ContractViolation, match="injective"):
        check_carry_migration(*args, 4, 3, 2)


def test_carry_migration_rejects_mutated_survivor():
    args = list(_migration_fixture())
    rem_n = args[3].copy()
    rem_n[0, 0] += 0.5  # survivor's remaining volume drifted
    args[3] = rem_n
    with pytest.raises(ContractViolation, match="bit-exactly"):
        check_carry_migration(*args, 4, 3, 2)


def test_carry_migration_rejects_hold_beyond_lag():
    args = list(_migration_fixture())
    hold_n = args[9].copy()
    hold_n[0, 1] = 9  # re-selected flow held far past the lag
    args[9] = hold_n
    with pytest.raises(ContractViolation, match="hold"):
        check_carry_migration(*args, 4, 3, 2)


def test_carry_migration_rejects_materialized_flow():
    args = list(_migration_fixture())
    row_n = args[1].copy()
    row_n[0, 3] = 0  # empty slot suddenly holds a flow
    args[1] = row_n
    with pytest.raises(ContractViolation, match="empty slot"):
        check_carry_migration(*args, 4, 3, 2)


# --------------------------------------------------------------------------- #
# producer validation
# --------------------------------------------------------------------------- #


def test_fail_links_validates_inputs():
    top = jellyfish(12, 6, 4, seed=0)
    with pytest.raises(ValueError, match="fraction"):
        fail_links(top, fraction=1.5)
    with pytest.raises(ValueError, match="remaining"):
        fail_links(top, n_links=top.n_edges + 1)
    with pytest.raises(ValueError, match="remaining"):
        fail_links(top, n_links=-2)
    with pytest.raises(ValueError, match="fraction"):
        fail_switches(top, fraction=-0.1)


def test_heal_links_validates_inputs():
    top = jellyfish(12, 6, 4, seed=0)
    failed = fail_links(top, seed=1, n_links=2)
    gone = failed.meta["edges_removed"]
    with pytest.raises(ValueError, match="already"):
        heal_links(failed, [tuple(failed.edges[0])])
    with pytest.raises(ValueError, match="self-loop"):
        heal_links(failed, [(3, 3)])
    with pytest.raises(ValueError, match="duplicate"):
        heal_links(failed, [gone[0], gone[0]])
    with pytest.raises(ValueError, match="in \\["):
        heal_links(failed, [(0, 99)])
    # degree budget: adding a new link to a fully-wired topology must fail
    have = {tuple(e) for e in top.edges.tolist()}
    extra = next(
        (u, v)
        for u in range(top.n_switches)
        for v in range(u + 1, top.n_switches)
        if (u, v) not in have
    )
    with pytest.raises(ValueError, match="net_degree"):
        heal_links(top, [extra])  # original top has no free ports


def test_validate_schedule_errors():
    with pytest.raises(ValueError, match="unknown event kind"):
        validate_schedule([Event(step=1, kind="meteor")], 10)
    with pytest.raises(ValueError, match="outside"):
        validate_schedule(
            [Event(step=10, kind="fail_links", n_links=1)], 10
        )
    with pytest.raises(ValueError, match="n_links or fraction"):
        validate_schedule([Event(step=1, kind="fail_links")], 10)
    with pytest.raises(ValueError, match="grow"):
        validate_schedule([Event(step=1, kind="expand")], 10)
    with pytest.raises(ValueError, match="heal_of"):
        validate_schedule([Event(step=1, kind="heal_links")], 10)
    with pytest.raises(ValueError, match="does not name"):
        validate_schedule(
            [Event(step=1, kind="heal_links", heal_of="nope")], 10
        )
    with pytest.raises(ValueError, match="does not name"):
        validate_schedule(
            [
                Event(step=5, kind="fail_links", n_links=1, tag="f"),
                Event(step=2, kind="heal_links", heal_of="f"),
            ],
            10,
        )
    with pytest.raises(ValueError, match="duplicate tag"):
        validate_schedule(
            [
                Event(step=1, kind="fail_links", n_links=1, tag="f"),
                Event(step=2, kind="fail_links", n_links=1, tag="f"),
            ],
            10,
        )
    validate_schedule(
        [
            Event(step=1, kind="fail_links", n_links=1, tag="f"),
            Event(step=3, kind="heal_links", heal_of="f"),
            Event(step=4, kind="expand", grow=2),
        ],
        10,
    )


def test_simulate_events_rejects_epoch_workloads():
    tops, comms = _instances(1)
    wl = steady_poisson(8, 1.0)
    wl.demand_epochs = np.ones((1, 4), np.float32)
    wl.epoch_of_step = np.zeros(8, np.int32)
    with pytest.raises(ValueError, match="demand-epoch"):
        simulate_events(tops, comms, [], wl, k=4)


# --------------------------------------------------------------------------- #
# MTBF/MTTR schedule generator
# --------------------------------------------------------------------------- #


def test_poisson_failure_schedule_deterministic():
    a = poisson_failure_schedule(200, mtbf_steps=12.0, mttr_steps=6.0, seed=4)
    b = poisson_failure_schedule(200, mtbf_steps=12.0, mttr_steps=6.0, seed=4)
    assert a == b
    c = poisson_failure_schedule(200, mtbf_steps=12.0, mttr_steps=6.0, seed=5)
    assert a != c
    validate_schedule(a, 200)
    steps = [e.step for e in a]
    assert steps == sorted(steps)
    fails = [e for e in a if e.kind == "fail_links"]
    assert fails and fails[0].step == 1
    heals = {e.heal_of: e.step for e in a if e.kind == "heal_links"}
    fail_steps = {e.tag: e.step for e in fails}
    for tag, hs in heals.items():
        assert hs > fail_steps[tag]
    # every heal pairs with exactly one failure; unmatched heals never occur
    assert set(heals) <= set(fail_steps)


def test_poisson_failure_schedule_validates():
    with pytest.raises(ValueError, match="mtbf"):
        poisson_failure_schedule(100, mtbf_steps=0.0)
    with pytest.raises(ValueError, match="mttr"):
        poisson_failure_schedule(100, mtbf_steps=5.0, mttr_steps=-1.0)
    assert poisson_failure_schedule(0, mtbf_steps=5.0) == []


# --------------------------------------------------------------------------- #
# REPRO_SIM_EVENT_* env validation (import-time, subprocess)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("var", ["REPRO_SIM_EVENT_LAG",
                                 "REPRO_SIM_EVENT_MAX_SEG"])
def test_event_env_validated_at_import(var):
    import os
    import pathlib
    import subprocess
    import sys

    root = pathlib.Path(__file__).resolve().parent.parent
    for bad in ("soon", "-3", "1.5"):
        env = dict(os.environ, **{var: bad})
        env["PYTHONPATH"] = str(root / "src")
        proc = subprocess.run(
            [sys.executable, "-c", "import repro.sim"],
            env=env, capture_output=True, text=True, cwd=str(root),
        )
        assert proc.returncode != 0, (var, bad)
        assert var in proc.stderr, (var, bad)
