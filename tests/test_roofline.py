"""Roofline machinery tests: HLO parser correctness on synthetic programs and
a real (tiny-mesh) lowered model; dry-run integration via subprocess."""

import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.roofline.analysis import HW, roofline_terms
from repro.roofline.hlo_stats import analyze_hlo

SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")


def test_roofline_terms_math():
    t = roofline_terms(197e12, 819e9, 50e9, HW())
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    t2 = roofline_terms(1e12, 1e12, 0.0, HW())
    assert t2["dominant"] == "memory"
    t3 = roofline_terms(0, 0, 1, HW(), fabric_efficiency=0.5)
    assert t3["collective_s"] == pytest.approx(1 / 25e9)


def test_hlo_parser_counts_loop_trips():
    """Scanned matmul: flops must scale with trip count (cost_analysis does
    not do this — the reason hlo_stats exists)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, json, sys
sys.path.insert(0, %r)
from repro.roofline.hlo_stats import analyze_hlo

def f(x, w):
    def body(c, wl):
        return jnp.dot(c, wl).astype(jnp.bfloat16), None
    y, _ = jax.lax.scan(body, x, w)
    return y.astype(jnp.float32).sum()

results = {}
for L in (2, 8):
    x = jnp.zeros((128, 256), jnp.bfloat16)
    w = jnp.zeros((L, 256, 256), jnp.bfloat16)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    st = analyze_hlo(txt, 4)
    results[L] = st.flops
print(json.dumps(results))
""" % SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": SRC},
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    expected = {L: L * 2 * 128 * 256 * 256 for L in (2, 8)}
    for L in ("2", "8"):
        assert res[L] == pytest.approx(expected[int(L)], rel=0.05), res


@pytest.mark.slow
def test_dryrun_single_cell_subprocess(tmp_path):
    """Integration: one real dry-run cell (smallest arch) end to end."""
    out = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "internvl2-1b", "--shape", "decode_32k",
            "--out", str(tmp_path), "--force",
        ],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": SRC},
        timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    blob = json.loads(
        (tmp_path / "internvl2-1b__decode_32k__pod16x16.json").read_text()
    )
    assert blob["status"] == "ok"
    assert blob["n_devices"] == 256
    r = blob["roofline"]
    assert r["dominant"] in ("compute", "memory", "collective")
    assert blob["hlo_stats"]["flops_per_device"] > 0
    # one decode token on 256 chips of a 0.5B model must be fast
    assert max(r["compute_s"], r["memory_s"]) < 1.0


def test_collective_parser_on_synthetic_hlo():
    txt = """
HloModule test

ENTRY %main.1 (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[4096]{0} all-gather(%ar), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %cp = f32[1024]{0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    st = analyze_hlo(txt, 8)
    kinds = {c.kind: c for c in st.collectives}
    assert kinds["all-reduce"].group_size == 4
    assert kinds["all-gather"].group_size == 4
    assert kinds["all-gather"].result_bytes == 4096 * 4
    # wire: AR 2*4096*3/4 + AG 16384*3/4 + CP 4096
    want = 2 * 4096 * 3 / 4 + 16384 * 3 / 4 + 4096
    assert st.wire_bytes == pytest.approx(want)
