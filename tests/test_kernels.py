"""Per-kernel validation: shape/dtype sweeps of the Pallas kernels
(interpret mode on CPU) against the pure-jnp ref.py oracles."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.congestion import congestion_pallas
from repro.kernels.minplus import minplus_pallas
from repro.kernels.power import matmul_pallas
from repro.kernels import ops

RNG = np.random.default_rng(42)

# (m, k, n) shape sweep: unaligned, degenerate, and tile-straddling cases.
SHAPES = [
    (8, 8, 8),
    (16, 16, 16),
    (17, 5, 23),
    (1, 64, 1),
    (33, 40, 29),
    (64, 64, 64),
    (70, 1, 70),
]
BLOCKS = [8, 16, 32]


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("block", BLOCKS)
def test_minplus_matches_ref(shape, block):
    m, k, n = shape
    a = jnp.asarray(RNG.uniform(0, 100, (m, k)).astype(np.float32))
    b = jnp.asarray(RNG.uniform(0, 100, (k, n)).astype(np.float32))
    got = minplus_pallas(a, b, bm=block, bn=block, bk=block, interpret=True)
    want = ref.minplus_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_minplus_with_inf_entries():
    # +inf entries (unreachable) must flow through the tropical product
    a = jnp.asarray([[0.0, np.inf], [1.0, 0.0]], dtype=jnp.float32)
    got = minplus_pallas(a, a, bm=8, bn=8, bk=8, interpret=True)
    want = ref.minplus_ref(a, a)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_matmul_matches_ref(shape, dtype):
    m, k, n = shape
    a = jnp.asarray(RNG.standard_normal((m, k)).astype(dtype))
    b = jnp.asarray(RNG.standard_normal((k, n)).astype(dtype))
    got = matmul_pallas(a, b, bm=16, bn=16, bk=16, interpret=True)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bf16", [False, True])
def test_matmul_bf16_inputs(bf16):
    a = jnp.asarray(RNG.standard_normal((40, 24)), dtype=jnp.bfloat16 if bf16 else jnp.float32)
    b = jnp.asarray(RNG.standard_normal((24, 56)), dtype=jnp.bfloat16 if bf16 else jnp.float32)
    got = matmul_pallas(a, b, bm=16, bn=16, bk=16, interpret=True)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize("pe", [(10, 7), (64, 64), (100, 60), (37, 129), (1, 1)], ids=str)
@pytest.mark.parametrize("block", [16, 32])
def test_congestion_matches_ref(pe, block):
    P, E = pe
    B = jnp.asarray((RNG.uniform(size=(P, E)) < 0.15).astype(np.float32))
    r = jnp.asarray(RNG.uniform(size=P).astype(np.float32))
    w = jnp.asarray(RNG.uniform(size=E).astype(np.float32))
    lg, cg = congestion_pallas(B, r, w, bp=block, be=block, interpret=True)
    lw, cw = ref.congestion_ref(B, r, w)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lw), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cg), np.asarray(cw), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "shape", [(3, 10, 7), (1, 64, 64), (4, 37, 129), (2, 1, 1)], ids=str
)
@pytest.mark.parametrize("block", [16, 32])
def test_congestion_batched_matches_ref(shape, block):
    """Stacked rank-3 incidence: one fused pass per batch member."""
    Bt, P, E = shape
    B = jnp.asarray((RNG.uniform(size=(Bt, P, E)) < 0.15).astype(np.float32))
    r = jnp.asarray(RNG.uniform(size=(Bt, P)).astype(np.float32))
    w = jnp.asarray(RNG.uniform(size=(Bt, E)).astype(np.float32))
    lg, cg = congestion_pallas(B, r, w, bp=block, be=block, interpret=True)
    lw, cw = ref.congestion_ref(B, r, w)
    assert lg.shape == (Bt, E) and cg.shape == (Bt, P)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lw), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cg), np.asarray(cw), rtol=1e-5, atol=1e-5)


def test_congestion_batched_members_match_single():
    """Each rank-3 member equals its own rank-2 solve (both backends)."""
    Bt, P, E = 3, 23, 31
    B = (RNG.uniform(size=(Bt, P, E)) < 0.2).astype(np.float32)
    r = RNG.uniform(size=(Bt, P)).astype(np.float32)
    w = RNG.uniform(size=(Bt, E)).astype(np.float32)
    lb, cb = ref.congestion_ref(jnp.asarray(B), jnp.asarray(r), jnp.asarray(w))
    for b in range(Bt):
        l1, c1 = ref.congestion_ref(
            jnp.asarray(B[b]), jnp.asarray(r[b]), jnp.asarray(w[b])
        )
        np.testing.assert_allclose(np.asarray(lb[b]), np.asarray(l1), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(cb[b]), np.asarray(c1), rtol=1e-6)


def test_congestion_loads_matches_fused():
    """Loads-only entry point (the sim waterfilling's primitive) agrees
    with the fused reference's loads half, rank-2 and rank-3, and with the
    interpret-mode kernel path."""
    Bt, P, E = 3, 23, 31
    B3 = jnp.asarray((RNG.uniform(size=(Bt, P, E)) < 0.2).astype(np.float32))
    r3 = jnp.asarray(RNG.uniform(size=(Bt, P)).astype(np.float32))
    want3 = ref.congestion_ref(B3, r3, jnp.zeros((Bt, E)))[0]
    np.testing.assert_allclose(
        np.asarray(ops.congestion_loads(B3, r3, backend="ref")),
        np.asarray(want3), rtol=1e-5, atol=1e-6,
    )
    B2, r2 = B3[0], r3[0]
    want2 = ref.congestion_ref(B2, r2, jnp.zeros(E))[0]
    np.testing.assert_allclose(
        np.asarray(ops.congestion_loads(B2, r2, backend="ref")),
        np.asarray(want2), rtol=1e-5, atol=1e-6,
    )
    got_k = ops.congestion_loads(B2, r2, backend="pallas", bp=16, be=16,
                                 interpret=True)
    np.testing.assert_allclose(
        np.asarray(got_k), np.asarray(want2), rtol=1e-5, atol=1e-5
    )


def test_preferred_congestion_backend_batch_aware():
    # CPU: batched asks answer 'gather' (PathSystemBatch fan-in tables);
    # single-instance answers are unchanged
    single = ops.preferred_congestion_backend(1000, 1000)
    assert single in ("dense", "scatter")
    assert ops.preferred_congestion_backend(1000, 1000, n_batch=1) == single
    assert ops.preferred_congestion_backend(1000, 1000, n_batch=16) == "gather"


def test_apsp_minplus_matches_blas_bfs():
    from repro.core import apsp_hops, jellyfish

    top = jellyfish(48, 8, 5, seed=7)
    d_ref = apsp_hops(top.adjacency())
    d_mp = np.asarray(ops.apsp_minplus(top.adjacency(), backend="ref"))
    assert np.array_equal(np.isinf(d_ref), np.isinf(d_mp))
    finite = ~np.isinf(d_ref)
    np.testing.assert_array_equal(d_ref[finite], d_mp[finite])


def test_apsp_minplus_blocked_matches_apsp_ref():
    """Tiled int16 driver == dense jnp squaring oracle (kernel-level parity)."""
    from repro.core import jellyfish

    top = jellyfish(40, 8, 5, seed=11)
    d_ref = np.asarray(ref.apsp_ref(jnp.asarray(top.adjacency())))
    d_blk = ops.apsp_minplus_blocked(top.adjacency(), bm=16, bn=24, bk=16)
    assert d_blk.dtype == np.int16
    inf16 = np.iinfo(np.int16).max
    assert np.array_equal(np.isinf(d_ref), d_blk == inf16)
    finite = ~np.isinf(d_ref)
    np.testing.assert_array_equal(d_ref[finite], d_blk[finite].astype(np.float32))


def test_minplus_integer_dtype_raises():
    a = jnp.ones((8, 8), jnp.int16)
    with pytest.raises(ValueError, match="floating point"):
        minplus_pallas(a, a, bm=8, bn=8, bk=8, interpret=True)
    with pytest.raises(ValueError, match="floating point"):
        ref.minplus_ref(a, a)


def test_power_iteration_lambda2_matches_dense_eig():
    from repro.core import jellyfish

    top = jellyfish(40, 8, 5, seed=9)
    a = top.adjacency().astype(np.float64)
    lap = np.diag(a.sum(1)) - a
    lam2_exact = np.sort(np.linalg.eigvalsh(lap))[1]
    lam2_ops = float(ops.power_iteration_lambda2(top.adjacency(), iters=400, backend="ref"))
    np.testing.assert_allclose(lam2_ops, lam2_exact, rtol=1e-3)


def test_ops_auto_dispatch_runs_on_cpu():
    a = jnp.ones((4, 4))
    assert np.asarray(ops.minplus(a, a)).shape == (4, 4)
    assert np.asarray(ops.matmul(a, a)).shape == (4, 4)
    l, c = ops.congestion(a, jnp.ones(4), jnp.ones(4))
    assert l.shape == (4,) and c.shape == (4,)
