"""Tests for the ``repro.sim`` subsystem (paper §3: Table 1, Fig 9).

Covers the four contracts of the new time-domain engine:

* waterfilling invariants — feasibility, the max-min bottleneck
  certificate (every flow is rate-limited by a saturated link on its path
  where it holds a maximal rate), and order invariance of the allocation;
* steady-state parity with the MW solver — persistent permutation traffic
  placed at the MW-optimal split waterfills to the MW concurrent alpha
  within 2% on RRG(256, 24, 18);
* ECMP determinism — golden integer-mixing hash values, cross-process
  stability under different PYTHONHASHSEEDs, and bit-identical ECMP path
  sets across APSP backends and enumeration shards (the
  ``tests/test_apsp_blocked.py`` parity discipline);
* engine plumbing — conservation accounting across policies, batched
  multi-seed scans, workload generators (churn/tenant scenarios riding
  ``update_path_system``), and ``REPRO_SIM_*`` import-time validation.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import (
    build_path_system,
    fattree,
    jellyfish,
    mw_concurrent_flow,
    random_permutation_traffic,
)
from repro.core.routing import PathSystem, clear_routing_cache, set_apsp_backend
from repro.sim import (
    SimConfig,
    ecmp_group_sizes,
    ecmp_path_system,
    fct_percentiles,
    flow_hash,
    hash_select_rows,
    path_diversity,
    per_commodity_throughput,
    simulate,
    steady_poisson,
    steady_state_throughput,
    waterfill_rates,
)
from repro.sim.workloads import (
    diurnal_wave,
    elephant_mice,
    permutation_churn,
    run_tenant_churn,
    tenant_churn_segments,
)


def _small_ps(seed=0, n=60, ports=10, net=6, k=8):
    top = jellyfish(n, ports, net, seed=seed)
    comm = random_permutation_traffic(top, seed=seed + 1)
    return build_path_system(top, comm, k=k)


# --------------------------------------------------------------------------- #
# waterfilling invariants
# --------------------------------------------------------------------------- #


def _bottleneck_certificate(ps, rates, loads, nflow):
    """Max-min certificate: each flow's rate is limited by a saturated link
    on its path at which the flow's rate is maximal among crossing flows."""
    E2 = ps.n_slots
    rel = loads[:E2] * 1.0  # unit capacities throughout the tests
    slot_max = np.zeros(E2 + 1)
    for p in range(ps.n_paths):
        if nflow[p] <= 0:
            continue
        hops = ps.path_edges[p][ps.path_edges[p] < E2]
        np.maximum.at(slot_max, hops, rates[p])
    ok = np.ones(ps.n_paths, dtype=bool)
    for p in range(ps.n_paths):
        if nflow[p] <= 0:
            continue
        hops = ps.path_edges[p][ps.path_edges[p] < E2]
        ok[p] = bool(
            np.any((rel[hops] >= 1.0 - 1e-3)
                   & (rates[p] >= slot_max[hops] - 1e-4))
        )
    return ok


def test_waterfill_feasible_and_bottlenecked():
    ps = _small_ps()
    nflow = np.zeros((1, ps.n_paths), np.float32)
    nflow[0] = ps.demands[ps.path_owner]
    rates, loads = waterfill_rates([ps], n_flows_per_path=nflow, wf_iters=64)
    r, ld = rates[0, : ps.n_paths], loads[0, : ps.n_slots]
    # feasibility: no directed slot above its (unit) capacity
    assert ld.max() <= 1.0 + 1e-4
    assert (r[nflow[0] > 0] > 0).all()
    ok = _bottleneck_certificate(ps, r, ld, nflow[0])
    assert ok.all(), f"{(~ok).sum()} flows not bottlenecked at a saturated link"


def test_waterfill_order_invariant():
    ps = _small_ps(seed=3)
    rng = np.random.default_rng(0)
    perm = rng.permutation(ps.n_paths)
    shuffled = PathSystem(
        n_edges=ps.n_edges,
        path_edges=ps.path_edges[perm],
        path_len=ps.path_len[perm],
        path_owner=ps.path_owner[perm],
        demands=ps.demands,
        capacities=ps.capacities,
        n_commodities=ps.n_commodities,
        src=ps.src,
        dst=ps.dst,
        unrouted=ps.unrouted,
    )
    nf = ps.demands[ps.path_owner].astype(np.float32)
    r1, _ = waterfill_rates([ps], n_flows_per_path=nf[None, :], wf_iters=64)
    r2, _ = waterfill_rates(
        [shuffled], n_flows_per_path=nf[perm][None, :], wf_iters=64
    )
    np.testing.assert_allclose(
        r1[0, : ps.n_paths][perm], r2[0, : ps.n_paths], rtol=1e-5, atol=1e-6
    )


def test_waterfill_batch_matches_single():
    a, b = _small_ps(seed=1), _small_ps(seed=2, n=40, ports=10, net=6)
    ra, _ = waterfill_rates([a], wf_iters=32)
    rb, _ = waterfill_rates([b], wf_iters=32)
    rab, _ = waterfill_rates([a, b], wf_iters=32)
    np.testing.assert_allclose(
        rab[0, : a.n_paths], ra[0, : a.n_paths], rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        rab[1, : b.n_paths], rb[0, : b.n_paths], rtol=1e-5, atol=1e-6
    )


@pytest.mark.slow
def test_steady_state_matches_mw_alpha():
    """Persistent permutation traffic at the MW-optimal split waterfills to
    the MW concurrent alpha within 2% on RRG(256, 24, 18) — the sim's
    capacity accounting and the MW loads model agree end to end."""
    top = jellyfish(256, 24, 18, seed=0)
    comm = random_permutation_traffic(top, seed=1)
    ps = build_path_system(top, comm, k=8, max_slack=3)
    mw = mw_concurrent_flow(ps, iters=400)
    owner = ps.path_owner
    tot = np.bincount(owner, weights=mw.rates, minlength=ps.n_commodities)
    split = mw.rates / np.maximum(tot[owner], 1e-12)
    nflow = (ps.demands[owner] * split).astype(np.float32)[None, :]
    rates, loads = waterfill_rates([ps], n_flows_per_path=nflow, wf_iters=32)
    delivered = np.bincount(
        owner,
        weights=nflow[0] * rates[0, : ps.n_paths],
        minlength=ps.n_commodities,
    )
    norm_min = float((delivered / ps.demands).min())
    assert loads.max() <= 1.0 + 1e-4
    assert abs(norm_min - mw.alpha) <= 0.02 * mw.alpha, (
        f"sim steady-state min normalized throughput {norm_min:.4f} vs "
        f"mw alpha {mw.alpha:.4f}"
    )


def test_loads_fn_matches_fused_backends():
    """The loads-only closure (sim waterfilling) equals the fused
    congestion closure's loads half — BIT-exactly on the order-preserving
    backends, to float tolerance on dense."""
    import jax.numpy as jnp

    from repro.core.flow import (
        PathSystemBatch,
        make_congestion_fn_batch,
        make_loads_fn_batch,
    )

    batch = PathSystemBatch.from_systems(
        [_small_ps(seed=1), _small_ps(seed=2, n=40, ports=10, net=6)]
    )
    B, S = batch.n_batch, batch.s_max
    pe = jnp.asarray(batch.path_edges)
    tab = jnp.asarray(batch.slot_gather)
    rng = np.random.default_rng(0)
    rates = jnp.asarray(rng.random((B, batch.p_max)).astype(np.float32))
    zeros = jnp.zeros((B, S), jnp.float32)
    for be in ("gather", "scatter", "dense"):
        fused = make_congestion_fn_batch(pe, S, B, be, tab)
        loads_fn = make_loads_fn_batch(pe, S, B, be, tab)
        want = np.asarray(fused(rates, zeros)[0])
        got = np.asarray(loads_fn(rates))
        if be == "dense":
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        else:
            np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------- #
# ECMP: hash determinism + path-set parity across APSP backends
# --------------------------------------------------------------------------- #

_HASH_SRC = np.array([0, 3, 17, 250, 511], dtype=np.uint32)
_HASH_DST = np.array([1, 7, 42, 13, 509], dtype=np.uint32)
_HASH_FID = np.array([0, 1, 2**20, 12345, 4294967295], dtype=np.uint32)
#: Golden values: any change silently reshuffles every ECMP flow placement.
_HASH_GOLDEN_5EED = [2060987080, 45655268, 3184681298, 105157940, 3795607632]
_HASH_GOLDEN_0 = [208060452, 2317150453, 3607758292, 2622168110, 44152540]


def test_flow_hash_golden_values():
    got = flow_hash(_HASH_SRC, _HASH_DST, _HASH_FID, 0x5EED)
    assert got.dtype == np.uint32
    assert got.tolist() == _HASH_GOLDEN_5EED
    assert flow_hash(_HASH_SRC, _HASH_DST, _HASH_FID, 0).tolist() == (
        _HASH_GOLDEN_0
    )


def test_flow_hash_jax_matches_numpy():
    import jax
    import jax.numpy as jnp

    args = (jnp.asarray(_HASH_SRC), jnp.asarray(_HASH_DST),
            jnp.asarray(_HASH_FID))
    eager = np.asarray(flow_hash(*args, 0x5EED))
    jitted = np.asarray(
        jax.jit(lambda a, b, c: flow_hash(a, b, c, 0x5EED))(*args)
    )
    assert eager.tolist() == _HASH_GOLDEN_5EED
    assert jitted.tolist() == _HASH_GOLDEN_5EED


def test_flow_hash_stable_across_processes():
    """The hash must not depend on process state (PYTHONHASHSEED et al.)."""
    import os
    import pathlib
    import subprocess
    import sys

    root = pathlib.Path(__file__).resolve().parent.parent
    prog = (
        "import numpy as np\n"
        "from repro.sim import flow_hash\n"
        "print(flow_hash(np.uint32(17), np.uint32(42), np.uint32(7), "
        "0x5EED))\n"
    )
    outs = set()
    for hash_seed in ("0", "42"):
        env = dict(os.environ, PYTHONHASHSEED=hash_seed)
        env["PYTHONPATH"] = str(root / "src")
        proc = subprocess.run(
            [sys.executable, "-c", prog], env=env, capture_output=True,
            text=True, cwd=str(root),
        )
        assert proc.returncode == 0, proc.stderr
        outs.add(proc.stdout.strip())
    assert len(outs) == 1
    expected = int(flow_hash(np.uint32(17), np.uint32(42), np.uint32(7),
                             0x5EED))
    assert outs.pop() == str(expected)


def test_ecmp_sets_identical_across_apsp_backends():
    """ECMP path sets are a pure function of the graph — bit-identical
    across APSP backends (dense / blocked / minplus_blocked on CPU)."""
    top = jellyfish(72, 12, 8, seed=5)
    comm = random_permutation_traffic(top, seed=6)
    results = {}
    for be in ("dense", "blocked", "minplus_blocked"):
        prev = set_apsp_backend(be)
        clear_routing_cache()
        try:
            results[be] = ecmp_path_system(top, comm, n_ways=64)
        finally:
            set_apsp_backend(prev)
    clear_routing_cache()
    base = results["dense"]
    for be in ("blocked", "minplus_blocked"):
        got = results[be]
        assert np.array_equal(base.path_edges, got.path_edges), be
        assert np.array_equal(base.path_owner, got.path_owner), be
        assert np.array_equal(base.path_len, got.path_len), be


def test_ecmp_sets_identical_across_shards(monkeypatch):
    """Tiny frontier tiles force many dst shards; path sets must not move."""
    from repro.core import routing

    top = jellyfish(72, 12, 8, seed=7)
    comm = random_permutation_traffic(top, seed=8)
    clear_routing_cache()
    base = ecmp_path_system(top, comm, n_ways=64, cache=False)
    monkeypatch.setattr(routing, "_FRONTIER_TILE_BYTES", 1 << 12)
    clear_routing_cache()
    sharded = ecmp_path_system(top, comm, n_ways=64, cache=False)
    assert np.array_equal(base.path_edges, sharded.path_edges)
    assert np.array_equal(base.path_owner, sharded.path_owner)


def test_ecmp_groups_on_fattree_analytic():
    k = 6
    ft = fattree(k)
    comm = random_permutation_traffic(ft, seed=0)
    eps = ecmp_path_system(ft, comm, n_ways=(k // 2) ** 2)
    groups = ecmp_group_sizes(eps)
    kept = ~eps.unrouted
    src, dst = eps.src[kept], eps.dst[kept]
    inter = (src // k) != (dst // k)
    assert (groups[inter] == (k // 2) ** 2).all()
    assert (groups[~inter] == k // 2).all()
    # every ECMP path is shortest: lengths match the pod structure
    assert (eps.path_len[np.isin(eps.path_owner, np.flatnonzero(inter))]
            == 4).all()


def test_hash_select_rows_deterministic_and_in_group():
    ps = ecmp_path_system(
        jellyfish(48, 10, 6, seed=2).copy(),
        random_permutation_traffic(jellyfish(48, 10, 6, seed=2), seed=3),
        n_ways=16,
    )
    rows = hash_select_rows(ps, salt=1)
    again = hash_select_rows(ps, salt=1)
    assert np.array_equal(rows, again)
    # every selected row belongs to the flow's own commodity
    d = np.maximum(np.round(ps.demands).astype(int), 1)
    ci = np.repeat(np.arange(ps.n_commodities), d)
    assert np.array_equal(ps.path_owner[rows], ci)
    # a different salt must actually reshuffle something
    assert not np.array_equal(rows, hash_select_rows(ps, salt=2))


def test_path_diversity_counts():
    ps = _small_ps(seed=9)
    div = path_diversity(ps)
    assert div["links_total"] == ps.n_edges
    assert 0 < div["links_covered"] <= ps.n_edges
    assert div["paths_per_commodity"].sum() == ps.n_paths
    # ECMP on the same instance covers no more links than 8-shortest
    top = jellyfish(60, 10, 6, seed=9)
    comm = random_permutation_traffic(top, seed=10)
    eps = ecmp_path_system(top, comm, n_ways=64)
    assert path_diversity(eps)["links_covered"] <= div["links_covered"]


# --------------------------------------------------------------------------- #
# engine: conservation, policies, batching, workloads
# --------------------------------------------------------------------------- #


def _tiny_systems(n_seeds=2):
    out = []
    for s in range(n_seeds):
        top = jellyfish(40, 10, 6, seed=s)
        comm = random_permutation_traffic(top, seed=s + 10)
        out.append(build_path_system(top, comm, k=8))
    return out


@pytest.mark.parametrize("policy", ["ecmp", "ksp_lc", "mptcp"])
def test_simulate_conservation(policy):
    systems = _tiny_systems()
    wl = steady_poisson(40, rate=5.0, size=12.0)
    cfg = SimConfig(max_flows=512, max_arrivals=8, wf_iters=8)
    res = simulate(systems, wl, policy=policy, config=cfg, seed=1)
    assert res.throughput.shape == (40, 2)
    assert (res.throughput >= -1e-6).all()
    # every admitted flow either completed or is still in flight
    in_flight = res.active[-1]
    assert ((res.fct_count + in_flight) == res.admitted).all()
    # volume conservation: admitted bytes = delivered bytes + bytes still
    # in flight; per-commodity offered accounting agrees with the totals
    total = res.throughput.sum(axis=0)
    offered = res.comm_offered.sum(axis=1)
    assert (total <= offered + 1e-3).all()
    np.testing.assert_allclose(
        res.comm_delivered.sum(axis=1), total, rtol=1e-5, atol=1e-3
    )
    # (mptcp splits a flow across subflows, conserving total size, so the
    # per-subflow admitted count is not directly comparable to size*count)
    if policy != "mptcp":
        np.testing.assert_allclose(offered, res.admitted * 12.0, rtol=1e-5)
    # FCT percentiles well-defined once flows completed
    if (res.fct_count > 0).all():
        p = fct_percentiles(res)
        assert np.isfinite(p).all()
        assert (p[:, 0] <= p[:, -1] + 1e-9).all()
    # per-commodity accounting adds up to the timeseries total
    np.testing.assert_allclose(
        per_commodity_throughput(res).sum(axis=1) * res.n_steps * res.dt,
        res.throughput.sum(axis=0),
        rtol=1e-4,
    )


def test_simulate_deterministic():
    systems = _tiny_systems(1)
    wl = steady_poisson(24, rate=4.0, size=10.0)
    cfg = SimConfig(max_flows=256, max_arrivals=8, wf_iters=6)
    a = simulate(systems, wl, policy="ecmp", config=cfg, seed=7)
    b = simulate(systems, wl, policy="ecmp", config=cfg, seed=7)
    np.testing.assert_array_equal(a.throughput, b.throughput)
    np.testing.assert_array_equal(a.fct_hist, b.fct_hist)
    c = simulate(systems, wl, policy="ecmp", config=cfg, seed=8)
    assert not np.array_equal(a.throughput, c.throughput)


def test_simulate_one_scan_many_seeds():
    """The acceptance shape: B instances advance in ONE scan, per-instance
    telemetry stays separated."""
    systems = _tiny_systems(4)
    wl = steady_poisson(32, rate=6.0, size=10.0)
    cfg = SimConfig(max_flows=512, max_arrivals=8, wf_iters=6)
    res = simulate(systems, wl, policy="ksp_lc", config=cfg, seed=0)
    assert res.throughput.shape == (32, 4)
    thr = steady_state_throughput(res)
    assert (thr > 0).all()
    util = res.util_sum / res.n_steps
    assert (util[res.slot_valid] <= 1.0 + 1e-4).all()


def test_workload_generators_validate():
    with pytest.raises(ValueError):
        diurnal_wave(10, 1.0, amplitude=1.5)
    with pytest.raises(ValueError):
        elephant_mice(10, 1.0, p_elephant=2.0)
    wl = diurnal_wave(50, 4.0, amplitude=0.5, period=25)
    assert wl.n_steps == 50 and wl.rate.min() >= 2.0 - 1e-5
    em = elephant_mice(10, 1.0, p_elephant=0.1)
    assert em.size_elephant > em.size_mice


def test_permutation_churn_epochs():
    tops = [jellyfish(40, 10, 6, seed=s) for s in (0, 1)]
    batch, wl = permutation_churn(
        tops, n_epochs=3, steps_per_epoch=8, rate=4.0, seed=2
    )
    assert wl.demand_epochs.shape[0] == 3
    assert wl.n_steps == 24
    assert wl.epoch_of_step.max() == 2
    # each epoch keeps demand only on a subset of the union commodities
    live = (wl.demand_epochs > 0).sum(axis=2)
    assert (live > 0).all()
    res = simulate(
        batch, wl, policy="ecmp",
        config=SimConfig(max_flows=256, max_arrivals=8, wf_iters=6), seed=0,
    )
    assert res.throughput.shape == (24, 2)
    assert res.admitted.sum() > 0


def test_tenant_churn_rides_delta_routing():
    tops = [jellyfish(24, 10, 6, seed=s) for s in (0, 1)]
    segments = tenant_churn_segments(tops, n_events=2, grow=1, seed=3)
    assert len(segments) == 3
    # arrival event grew every instance by one switch
    assert all(
        b.n_commodities >= a.n_commodities
        for a, b in zip(segments[0]["systems"], segments[1]["systems"])
    )
    # the delta-routed system carries a row_map (update_path_system ran)
    assert segments[1]["systems"][0].row_map is not None
    # departure event zeroed a slice of demand weights
    assert segments[2]["demands"][0].min() == 0.0
    results = run_tenant_churn(
        segments, steps_per_segment=10, rate=3.0,
        config=SimConfig(max_flows=256, max_arrivals=8, wf_iters=6),
    )
    assert len(results) == 3
    assert all(r.throughput.shape[0] == 10 for r in results)


# --------------------------------------------------------------------------- #
# REPRO_SIM_* env validation (import-time, subprocess)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("var", ["REPRO_SIM_MAX_STEPS", "REPRO_SIM_MAX_BATCH"])
def test_sim_env_validated_at_import(var):
    import os
    import pathlib
    import subprocess
    import sys

    root = pathlib.Path(__file__).resolve().parent.parent
    for bad in ("ten", "0", "-3"):
        env = dict(os.environ, **{var: bad})
        env["PYTHONPATH"] = str(root / "src")
        proc = subprocess.run(
            [sys.executable, "-c", "import repro.sim"],
            env=env, capture_output=True, text=True, cwd=str(root),
        )
        assert proc.returncode != 0, (var, bad)
        assert var in proc.stderr, (var, bad)


def test_sim_caps_enforced(monkeypatch):
    from repro.sim import engine

    systems = _tiny_systems(1)
    monkeypatch.setattr(engine, "SIM_MAX_STEPS", 8)
    with pytest.raises(ValueError, match="REPRO_SIM_MAX_STEPS"):
        engine.simulate(systems, steady_poisson(9, 1.0))
    monkeypatch.setattr(engine, "SIM_MAX_STEPS", 200_000)
    monkeypatch.setattr(engine, "SIM_MAX_BATCH", 1)
    with pytest.raises(ValueError, match="REPRO_SIM_MAX_BATCH"):
        engine.simulate(_tiny_systems(2), steady_poisson(4, 1.0))


def test_simulate_rejects_unknown_policy():
    with pytest.raises(ValueError, match="policy"):
        simulate(_tiny_systems(1), steady_poisson(4, 1.0), policy="spray")
