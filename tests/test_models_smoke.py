"""Per-architecture smoke tests (required deliverable f): every assigned
architecture instantiates a REDUCED same-family config and runs one forward +
one train step on CPU, asserting output shapes and finiteness.  Plus
family-specific correctness: decode-vs-prefill cache consistency and the
chunked-recurrence oracles."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get, names
from repro.models import decode_step, init_cache, init_params, loss_fn, prefill
from repro.models.frontends import encodec_stub_embeddings, vit_stub_embeddings
from repro.optim.adamw import adamw_init, adamw_update

ALL_ARCHS = names()
KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b=2, s=24):
    if cfg.frontend == "vit":
        return {
            "inputs_embeds": vit_stub_embeddings(KEY, b, cfg.d_model, 8, jnp.float32),
            "tokens": jax.random.randint(KEY, (b, s - 8), 0, cfg.vocab_size),
        }
    if cfg.frontend == "encodec":
        return {
            "inputs_embeds": encodec_stub_embeddings(KEY, b, s, cfg.d_model, jnp.float32),
            "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}


def test_all_ten_archs_registered():
    assert len(ALL_ARCHS) == 10


@pytest.mark.slow
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get(arch).reduced()
    params = init_params(cfg, KEY, jnp.float32)
    batch = _batch_for(cfg)

    loss, metrics = jax.jit(
        lambda p, b: loss_fn(p, b, cfg, dtype=jnp.float32)
    )(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert loss > 0

    # one full optimizer step; params must change and stay finite
    grads = jax.jit(
        jax.grad(lambda p, b: loss_fn(p, b, cfg, dtype=jnp.float32)[0])
    )(params, batch)
    opt = adamw_init(params)
    new_params, _, stats = adamw_update(grads, opt, params, lr=1e-3)
    assert bool(jnp.isfinite(stats["grad_norm"]))
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get(arch).reduced()
    params = init_params(cfg, KEY, jnp.float32)
    b, s = 2, 16
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}
    logits, cache = prefill(params, batch, cfg, max_len=s + 4, dtype=jnp.float32)
    assert logits.shape == (b, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    logits2, cache = decode_step(params, cache, tok, jnp.int32(s), cfg,
                                 dtype=jnp.float32)
    assert logits2.shape == (b, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch", ["qwen2.5-32b", "mixtral-8x22b", "rwkv6-1.6b", "recurrentgemma-2b",
             "qwen2-moe-a2.7b"]
)
def test_decode_matches_prefill(arch):
    """Teacher-forcing consistency: decode with cache == fresh prefill."""
    cfg = get(arch).reduced()
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)  # drop-free
    params = init_params(cfg, KEY, jnp.float32)
    toks = jax.random.randint(KEY, (2, 28), 0, cfg.vocab_size)
    sp = 24
    _, cache = prefill(params, {"tokens": toks[:, :sp]}, cfg, max_len=32,
                       dtype=jnp.float32)
    for i in range(3):
        want, _ = prefill(params, {"tokens": toks[:, : sp + i + 1]}, cfg,
                          max_len=32, dtype=jnp.float32)
        got, cache = decode_step(params, cache, toks[:, sp + i],
                                 jnp.int32(sp + i), cfg, dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=1e-4)


def test_wkv_chunked_matches_sequential_across_decay():
    from repro.models.rwkv6 import _wkv_chunked, wkv_sequential

    rng = np.random.default_rng(0)
    B, S, H, hd = 2, 45, 3, 8
    r, k, v = (
        jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
        for _ in range(3)
    )
    u = jnp.asarray(rng.standard_normal((H, hd)).astype(np.float32))
    S0 = jnp.asarray(rng.standard_normal((B, H, hd, hd)).astype(np.float32)) * 0.1
    for lo, hi in [(0.001, 0.5), (0.5, 3.0), (2.0, 6.0), (5.0, 10.0)]:
        logw = -jnp.asarray(rng.uniform(lo, hi, (B, S, H, hd)).astype(np.float32))
        o1, s1 = _wkv_chunked(r, k, v, logw, u, S0)
        o2, s2 = wkv_sequential(r, k, v, logw, u, S0)
        rel = float(jnp.max(jnp.abs(o1 - o2)) / (jnp.max(jnp.abs(o2)) + 1e-9))
        assert rel < 1e-4, (lo, hi, rel)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-4, atol=1e-4)


def test_rglru_associative_scan_matches_sequential():
    rng = np.random.default_rng(1)
    B, S, D = 2, 37, 16
    a = jnp.asarray(rng.uniform(0.2, 0.999, (B, S, D)).astype(np.float32))
    bx = jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32))
    h0 = jnp.asarray(rng.standard_normal((B, D)).astype(np.float32))

    def combine(l, r):
        a1, x1 = l
        a2, x2 = r
        return a1 * a2, a2 * x1 + x2

    A, X = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h_par = A * h0[:, None, :] + X
    # sequential oracle
    h = h0
    outs = []
    for t in range(S):
        h = a[:, t] * h + bx[:, t]
        outs.append(h)
    h_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_seq),
                               rtol=1e-5, atol=1e-5)


def test_moe_router_load_balance_aux():
    from repro.models.moe import moe_apply, moe_init

    cfg = get("mixtral-8x22b").reduced()
    p = moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model), jnp.float32)
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux))
    # aux loss ~1 for near-uniform routing at init (E * sum p_e f_e ~ 1)
    assert 0.5 < float(aux) < 2.0


def test_vocab_padding_properties():
    for arch in ALL_ARCHS:
        cfg = get(arch)
        assert cfg.vocab_padded >= cfg.vocab_size
        assert cfg.vocab_padded % 256 == 0
        assert cfg.vocab_padded - cfg.vocab_size < 256


def test_param_counts_close_to_billing():
    """Analytic param count ~ materialized param count (catches init drift)."""
    for arch in ALL_ARCHS:
        cfg = get(arch).reduced()
        params = init_params(cfg, KEY, jnp.float32)
        n = sum(p.size for p in jax.tree_util.tree_leaves(params))
        assert n > 0
