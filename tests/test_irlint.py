"""PR 8: IR-level static auditor (repro.analysis.irlint, rules JF100-JF105).

Four test groups:

* rule fixtures: every JF10x rule fires on a minimal bad jaxpr/fixture and
  stays silent on the corrected twin (mirroring the AST linter's fixture
  discipline; a completeness assert pins the fixture set to IR_RULES).
* HEAD audit: the tree at HEAD audits clean INCLUDING the checked-in
  compile-footprint budget — the CI ir-audit lane in test form.
* corruption: deliberately breaking a solver invariant (swapping _fold_sum
  for a raw jnp.sum, re-introducing a scatter under the gather backend) is
  caught by tracing alone — no solver runs.
* golden censuses: the three batched congestion backends and
  _path_cost_gather keep their exact primitive censuses (any change to the
  lowering of the bit-exactness-critical closures must be a deliberate,
  reviewed snapshot update).
"""

import json
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.irlint import (
    audit_case,
    audit_fold_tree,
    check_registration,
    compare_budget,
    iter_eqns,
    measure_case,
    primitive_census,
    run_audit,
    trace_case,
)
from repro.analysis.registry import (
    IR_RULES,
    SOLVER_MODULES,
    AuditCase,
    SolverEntry,
    registered_entries,
    solver_jit,
)
from repro.core import flow

ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")


@pytest.fixture
def fresh_traces():
    """Corruption tests monkeypatch trace-time globals: drop any cached
    jaxprs before AND after so neither direction sees a stale trace."""
    jax.clear_caches()
    yield
    jax.clear_caches()


def _audit_fn(fn, *args, backend=None, exempt=None):
    """Run the per-case rules on a bare function (toy-fixture harness)."""
    entry = SolverEntry(module="toy", attr=getattr(fn, "__name__", "fn"))
    case = AuditCase(
        label="t", make=lambda: (args, {}), backend=backend,
        exempt=exempt or {},
    )
    return audit_case(entry, case, jax.make_jaxpr(fn)(*args))


# --------------------------------------------------------------------------- #
# registry
# --------------------------------------------------------------------------- #


def test_registry_enumerates_all_solver_jits():
    entries = registered_entries()
    # the entry the old hand-maintained retrace list shipped without:
    assert "repro.kernels.admission.admission_pallas" in entries
    assert all(e.kind in ("jit", "wrapper") for e in entries.values())
    # every spec resolves to at least one concrete case
    for e in entries.values():
        assert e.spec is not None
        assert len(e.cases()) >= 1
    # wrappers participate in the audit but not the jit view
    from repro.analysis.retrace import named_solver_jits

    jits = named_solver_jits()
    assert "repro.kernels.ops.congestion" in entries
    assert "repro.kernels.ops.congestion" not in jits
    assert "repro.kernels.admission.admission_pallas" in jits
    assert all(hasattr(fn, "lower") for fn in jits.values())


def test_solver_jit_rejects_bad_kind():
    with pytest.raises(ValueError, match="kind"):
        solver_jit(kind="whatever")


# --------------------------------------------------------------------------- #
# rule fixtures: fire + silent per rule
# --------------------------------------------------------------------------- #


def test_jf101_fires_on_float_reduce_sum_and_dot():
    x = np.ones(5, np.float32)
    fired = _audit_fn(lambda v: jnp.sum(v), x)
    assert [f.rule for f in fired] == ["JF101"]
    assert "_fold_sum" in fired[0].message

    a = np.ones((4, 4), np.float32)
    fired = _audit_fn(lambda u, v: u @ v, a, a)
    assert [f.rule for f in fired] == ["JF101"]


def test_jf101_silent_on_fold_sum_and_int_sum():
    assert _audit_fn(flow._fold_sum, np.ones(5, np.float32)) == []
    # integer reductions are exactly associative — out of JF101's scope
    assert _audit_fn(lambda v: jnp.sum(v), np.ones(5, np.int32)) == []
    # a recorded exemption silences the rule (dense-backend contract)
    a = np.ones((4, 4), np.float32)
    assert _audit_fn(lambda u, v: u @ v, a, a,
                     exempt={"JF101": "dense by design"}) == []


def test_jf102_fires_on_scatter_under_gather_backend():
    def scat(x, idx):
        return jnp.zeros((8,), jnp.float32).at[idx].add(x)

    x = np.ones(4, np.float32)
    idx = np.arange(4, dtype=np.int32)
    fired = _audit_fn(scat, x, idx, backend="gather")
    assert [f.rule for f in fired] == ["JF102"]
    # same program under the scatter backend is the sanctioned path
    assert _audit_fn(scat, x, idx, backend="scatter") == []
    # and the gather backend's real accumulator is scatter-free
    fr = np.ones((2, 9), np.float32)
    table = np.full((8, 4), 8, np.int32)
    assert _audit_fn(flow._ordered_fan_in_sum, fr, table,
                     backend="gather") == []


def test_jf103_fires_on_f64_and_silences_on_f32():
    from jax.experimental import enable_x64

    with enable_x64():
        closed = jax.make_jaxpr(lambda v: v * 2.0)(np.ones(3, np.float64))
    entry = SolverEntry(module="toy", attr="f64")
    case = AuditCase(label="t", make=lambda: ((), {}))
    fired = audit_case(entry, case, closed)
    assert fired and all(f.rule == "JF103" for f in fired)

    assert _audit_fn(lambda v: v * 2.0, np.ones(3, np.float32)) == []


def test_jf104_fires_on_cond_and_callback_in_scan():
    def cond_in_scan(x):
        def body(c, _):
            c = jax.lax.cond(c[0] > 0.0, lambda v: v + 1.0,
                             lambda v: v - 1.0, c)
            return c, None

        c, _ = jax.lax.scan(body, x, None, length=2)
        return c

    fired = _audit_fn(cond_in_scan, np.ones(3, np.float32))
    assert [f.rule for f in fired] == ["JF104"]

    def cb_in_scan(x):
        def body(c, _):
            jax.debug.callback(lambda v: None, c)
            return c, None

        c, _ = jax.lax.scan(body, x, None, length=2)
        return c

    fired = _audit_fn(cb_in_scan, np.ones(3, np.float32))
    assert fired and all(f.rule == "JF104" for f in fired)

    def masked(x):  # the sanctioned select-masked twin
        def body(c, _):
            return jnp.where(c > 0.0, c + 1.0, c - 1.0), None

        c, _ = jax.lax.scan(body, x, None, length=2)
        return c

    assert _audit_fn(masked, np.ones(3, np.float32)) == []


def test_jf104_skips_pallas_kernel_when():
    # pl.when lowers to a cond INSIDE the pallas body — grid-static control
    # flow, not a host sync.  Prove the skip is load-bearing: the cond is
    # really there, and the audit still passes the case.
    entry = registered_entries()["repro.kernels.minplus.minplus_pallas"]
    case = entry.cases()[0]
    closed = trace_case(entry, case)
    pallas_conds = sum(
        1 for eqn, _, in_pallas in iter_eqns(closed.jaxpr)
        if in_pallas and eqn.primitive.name == "cond"
    )
    assert pallas_conds >= 1
    assert audit_case(entry, case, closed) == []


def test_jf100_fires_on_unregistered_jit(tmp_path):
    d = tmp_path / "repro" / "core"
    d.mkdir(parents=True)
    f = d / "newsolver.py"
    f.write_text("import jax\n\n@jax.jit\ndef step(x):\n    return x\n")
    fired = check_registration([str(tmp_path)], entries=registered_entries())
    assert [x.rule for x in fired] == ["JF100"]
    assert "SOLVER_MODULES" in fired[0].message  # module itself unlisted

    # a registered-module file whose jit is missing the decorator
    d2 = tmp_path / "repro" / "kernels"
    d2.mkdir(parents=True)
    (d2 / "minplus.py").write_text(
        "import jax\n\n@jax.jit\ndef rogue(x):\n    return x\n"
    )
    fired = check_registration([str(d2)], entries=registered_entries())
    assert [x.rule for x in fired] == ["JF100"]
    assert "@solver_jit" in fired[0].message

    # pragma escape hatch on the def line
    f.write_text(
        "import jax\n\n@jax.jit\n"
        "def step(x):  # repro-lint: disable=JF100\n    return x\n"
    )
    assert check_registration([str(f)], entries=registered_entries()) == []


def test_jf105_compare_budget_semantics():
    base = {"jaxpr_eqns": 100, "hlo_ops": 200, "flops": 0.0,
            "hbm_bytes": 1000.0, "whiles": 1}
    budget = {"tolerance": {"rel": 0.25, "abs": {"hlo_ops": 24}},
              "entries": {"m.f[x]": dict(base)}}

    # within tolerance (growth under rel+abs headroom): silent
    grown_ok = dict(base, hlo_ops=int(200 * 1.25) + 24)
    findings, diff = compare_budget({"m.f[x]": grown_ok}, budget)
    assert findings == [] and diff["ok"]

    # beyond tolerance: fires with the limit in the message
    grown_bad = dict(base, hlo_ops=int(200 * 1.25) + 25)
    findings, diff = compare_budget({"m.f[x]": grown_bad}, budget)
    assert [f.rule for f in findings] == ["JF105"]
    assert not diff["entries"]["m.f[x]"]["hlo_ops"]["ok"]

    # shrinkage never fails
    findings, _ = compare_budget({"m.f[x]": dict(base, hlo_ops=10)}, budget)
    assert findings == []

    # a measured case with no recorded budget fires
    findings, _ = compare_budget(
        {"m.f[x]": base, "m.g[y]": base}, budget)
    assert [f.rule for f in findings] == ["JF105"]
    assert "no recorded" in findings[0].message

    # stale recorded cases fire only on a complete (unfiltered) audit
    findings, _ = compare_budget({}, budget, complete=True)
    assert [f.rule for f in findings] == ["JF105"]
    assert "stale" in findings[0].message
    findings, _ = compare_budget({}, budget, complete=False)
    assert findings == []


def test_jf105_measure_roundtrips_on_a_real_entry():
    entry = registered_entries()["repro.kernels.ref.matmul_ref"]
    case = entry.cases()[0]
    m = measure_case(entry, case)
    assert m["jaxpr_eqns"] >= 1 and m["hlo_ops"] >= 1 and m["flops"] > 0
    budget = {"tolerance": {"rel": 0.25, "abs": {}},
              "entries": {"k[f32]": m}}
    findings, diff = compare_budget({"k[f32]": m}, budget)
    assert findings == [] and diff["ok"]


def test_every_ir_rule_has_fixtures():
    # fixture discipline mirror of the AST linter: each IR rule is exercised
    # by a dedicated fire/silent test above (JF100 registration, JF101-104
    # jaxpr rules, JF105 budget).  Keep this list in lockstep with IR_RULES.
    covered = {"JF100", "JF101", "JF102", "JF103", "JF104", "JF105"}
    assert covered == set(IR_RULES)


# --------------------------------------------------------------------------- #
# HEAD audit (the CI ir-audit lane in test form)
# --------------------------------------------------------------------------- #


def test_head_audits_clean_against_checked_in_budget(tmp_path):
    budget_path = ROOT / "artifacts" / "ir_budget.json"
    assert budget_path.exists(), "regenerate with --write-budget"
    diff_out = tmp_path / "diff.json"
    findings, diff = run_audit(
        [SRC], budget_path=str(budget_path), diff_out=str(diff_out)
    )
    assert findings == [], "\n".join(str(f) for f in findings)
    assert diff["ok"]
    assert json.loads(diff_out.read_text())["ok"]
    # every budgeted case is present in the checked-in file (no silent gaps)
    recorded = set(json.loads(budget_path.read_text())["entries"])
    budgeted = {
        f"{n}[{c.label}]" for n, e in registered_entries().items()
        for c in e.cases() if c.budget
    }
    assert recorded == budgeted


def test_registration_audit_clean_at_head():
    assert check_registration([SRC]) == []


# --------------------------------------------------------------------------- #
# corruption: invariant breaks are caught without running a solver
# --------------------------------------------------------------------------- #


def test_fold_sum_corruption_caught_statically(monkeypatch, fresh_traces):
    monkeypatch.setattr(flow, "_fold_sum",
                        lambda x: jnp.sum(x, axis=-1))
    # the structural tree check fires...
    tree = audit_fold_tree()
    assert tree and all(f.rule == "JF101" for f in tree)
    # ...and so does tracing the MW window that routes costs through it
    entry = registered_entries()["repro.core.flow._mw_window"]
    case = next(c for c in entry.cases() if c.label == "scatter")
    fired = audit_case(entry, case)
    assert any(f.rule == "JF101" for f in fired)


def test_gather_backend_scatter_regression_caught(monkeypatch, fresh_traces):
    def corrupt(fr, table):  # shape-correct stand-in that scatter-adds
        Bt, S = fr.shape[0], table.shape[-2]
        return jnp.zeros((Bt, S), jnp.float32).at[:, 0].add(fr[:, 0])

    monkeypatch.setattr(flow, "_ordered_fan_in_sum", corrupt)
    entry = registered_entries()["repro.core.flow._mw_window_batch"]
    case = next(c for c in entry.cases() if c.label == "gather")
    fired = audit_case(entry, case)
    assert any(f.rule == "JF102" for f in fired)


# --------------------------------------------------------------------------- #
# golden primitive censuses (congestion backends + _path_cost_gather)
# --------------------------------------------------------------------------- #

# Pinned at PR 8 on jax 0.4.37.  A census change here means the lowering of
# a bit-exactness-critical closure changed: update deliberately, with the
# same scrutiny as an artifacts/ir_budget.json refresh.
_GOLDEN = {
    "scatter": {
        "add": 8, "broadcast_in_dim": 5, "concatenate": 1, "gather": 3,
        "lt": 4, "pjit": 3, "reshape": 6, "scatter-add": 1, "select_n": 4,
        "slice": 4, "squeeze": 3,
    },
    "gather": {
        "add": 14, "broadcast_in_dim": 5, "concatenate": 2, "gather": 7,
        "lt": 7, "pjit": 7, "reshape": 8, "select_n": 7, "slice": 7,
        "squeeze": 7,
    },
    "dense": {"dot_general": 2, "pjit": 1},
    "path_cost_gather": {
        "add": 6, "broadcast_in_dim": 1, "gather": 3, "lt": 3, "pjit": 3,
        "reshape": 3, "select_n": 3, "slice": 3, "squeeze": 3,
    },
}


def _census_congestion(backend):
    pe3, _, _, inv2, _, slot_gather, _, _, _ = flow._ir_batch_args()
    B, P, _ = pe3.shape
    S = inv2.shape[1]
    kw = {}
    if backend == "gather":
        kw["slot_gather"] = jnp.asarray(slot_gather)
    fn = flow.make_congestion_fn_batch(jnp.asarray(pe3), S, B, backend, **kw)
    rates = np.ones((B, P), np.float32)
    prices = np.ones((B, S), np.float32)
    return primitive_census(jax.make_jaxpr(fn)(rates, prices))


@pytest.mark.parametrize("backend", ["scatter", "gather", "dense"])
def test_congestion_backend_census_stable(backend):
    assert _census_congestion(backend) == _GOLDEN[backend]


def test_congestion_census_invariants():
    # the properties behind the snapshots, stated directly: gather has no
    # scatter at all, scatter has exactly one (the load accumulation), and
    # neither bit-exact backend contracts through a float reduction
    scatter, gather = _census_congestion("scatter"), _census_congestion("gather")
    assert not any(k.startswith("scatter") for k in gather)
    assert scatter.get("scatter-add") == 1
    for census in (scatter, gather):
        assert "reduce_sum" not in census
        assert "dot_general" not in census


def test_path_cost_gather_census_stable():
    pe3, _, _, inv2, _, _, _, _, _ = flow._ir_batch_args()
    B = pe3.shape[0]
    S = inv2.shape[1]
    pr_pad = np.ones((B, S + 1), np.float32)
    census = primitive_census(jax.make_jaxpr(flow._path_cost_gather)(pr_pad, pe3))
    assert census == _GOLDEN["path_cost_gather"]
