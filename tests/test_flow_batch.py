"""Batched MW solver + speculative bisection validation.

Parity contract: ``mw_concurrent_flow_batch`` reproduces per-instance
``mw_concurrent_flow`` results — bit-exactly on the scatter backend (same
accumulation order) and on the gather backend (ordered fan-in sums match
the scatter association), including EXACT per-instance iteration counts
under the frozen-instance adaptive early-stop.  Plus ragged/empty/B=1
batches, the shared-topology fast path, the jit-churn window padding, the
speculative bisection's sequential-equality guarantee, the
REPRO_LP_PATH_LIMIT import validation, and the MPTCP warm start.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    PathSystemBatch,
    build_path_system,
    jellyfish,
    max_feasible,
    mw_concurrent_flow,
    mw_concurrent_flow_batch,
    random_permutation_traffic,
    speculative_max_feasible,
)
from repro.core.routing import PathSystem


def _systems(sizes, k=4, seed=3):
    out = []
    for i, n in enumerate(sizes):
        top = jellyfish(n, 10, 6, seed=i)
        out.append(
            build_path_system(top, random_permutation_traffic(top, seed=seed), k=k)
        )
    return out


def _empty_system():
    return PathSystem(
        n_edges=0,
        path_edges=np.zeros((0, 1), np.int32),
        path_len=np.zeros(0, np.int32),
        path_owner=np.zeros(0, np.int32),
        demands=np.zeros(0, np.float32),
        capacities=np.zeros(0, np.float32),
        n_commodities=0,
    )


# --------------------------------------------------------------------------- #
# batched-vs-sequential parity
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("backend", ["scatter", "gather", "dense"])
def test_batch_matches_sequential_fixed_budget(backend):
    systems = _systems((24, 40, 32))
    seq = [mw_concurrent_flow(ps, iters=120, backend="scatter") for ps in systems]
    bat = mw_concurrent_flow_batch(systems, iters=120, backend=backend)
    for s, b in zip(seq, bat):
        assert abs(s.alpha - b.alpha) <= 1e-5 * max(s.alpha, 1.0)
        assert s.iters == b.iters == 120
        # dense reassociates the incidence products (einsum), so its
        # trajectory drifts at float tolerance; scatter/gather are bit-exact
        tol = dict(rtol=5e-3, atol=1e-4) if backend == "dense" else dict(
            rtol=1e-6, atol=1e-7
        )
        np.testing.assert_allclose(s.rates, b.rates, **tol)


@pytest.mark.parametrize("backend", ["scatter", "gather"])
def test_batch_bit_exact_order_preserving_backends(backend):
    """scatter and gather reproduce the sequential accumulation order, so
    alpha agreement is BIT-level, not just tolerance-level."""
    systems = _systems((40, 60))
    seq = [mw_concurrent_flow(ps, iters=150, backend="scatter") for ps in systems]
    bat = mw_concurrent_flow_batch(systems, iters=150, backend=backend)
    for s, b in zip(seq, bat):
        assert s.alpha == b.alpha


def test_batch_adaptive_iteration_counts_agree_exactly():
    """Frozen-instance early-stop: every instance stops at the same window
    (same iteration count) its sequential adaptive solve would."""
    systems = _systems((24, 40, 60, 32))
    kw = dict(iters=300, early_stop=True, check_every=25, target_alpha=0.55)
    seq = [mw_concurrent_flow(ps, backend="scatter", **kw) for ps in systems]
    bat = mw_concurrent_flow_batch(systems, backend="gather", **kw)
    iters = sorted(b.iters for b in bat)
    assert iters[0] < iters[-1], "sizes chosen so freeze windows differ"
    for s, b in zip(seq, bat):
        assert s.iters == b.iters
        assert s.alpha == b.alpha


def test_batch_plateau_early_stop_agrees():
    systems = _systems((24, 40))
    kw = dict(iters=400, early_stop=True, check_every=50, rel_tol=5e-3,
              patience=1)
    seq = [mw_concurrent_flow(ps, backend="scatter", **kw) for ps in systems]
    bat = mw_concurrent_flow_batch(systems, backend="gather", **kw)
    for s, b in zip(seq, bat):
        assert s.iters == b.iters
        assert abs(s.alpha - b.alpha) <= 1e-6


def test_batch_warm_start_matches_sequential():
    from repro.core import fail_links, update_path_system

    tops = [jellyfish(n, 10, 6, seed=7 + i) for i, n in enumerate((40, 50))]
    comms = [random_permutation_traffic(t, seed=1) for t in tops]
    systems = [build_path_system(t, c, k=4) for t, c in zip(tops, comms)]
    warms = [mw_concurrent_flow(ps, iters=80) for ps in systems]
    failed = [fail_links(t, n_links=3, seed=9) for t in tops]
    deltas = [
        update_path_system(ps, t, f, c)
        for ps, t, f, c in zip(systems, tops, failed, comms)
    ]
    seq = [
        mw_concurrent_flow(ps, iters=60, backend="scatter", warm=w)
        for ps, w in zip(deltas, warms)
    ]
    bat = mw_concurrent_flow_batch(deltas, iters=60, backend="gather",
                                   warm=warms)
    for s, b in zip(seq, bat):
        assert s.alpha == b.alpha


# --------------------------------------------------------------------------- #
# ragged batches, padding edge cases
# --------------------------------------------------------------------------- #


def test_batch_with_empty_instance():
    systems = _systems((24, 40))
    mixed = [systems[0], _empty_system(), systems[1]]
    bat = mw_concurrent_flow_batch(mixed, iters=80)
    assert bat[1].alpha == 0.0 and len(bat[1].rates) == 0 and bat[1].iters == 0
    for ps, b in zip((systems[0], systems[1]), (bat[0], bat[2])):
        s = mw_concurrent_flow(ps, iters=80, backend="scatter")
        assert abs(s.alpha - b.alpha) <= 1e-6
        assert len(b.rates) == ps.n_paths


def test_batch_all_empty():
    out = mw_concurrent_flow_batch([_empty_system(), _empty_system()], iters=50)
    assert all(r.alpha == 0.0 and r.iters == 0 for r in out)


def test_batch_of_one():
    (ps,) = _systems((40,))
    s = mw_concurrent_flow(ps, iters=100, backend="scatter")
    (b,) = mw_concurrent_flow_batch([ps], iters=100)
    assert s.alpha == b.alpha
    np.testing.assert_allclose(s.rates, b.rates, rtol=1e-6, atol=1e-7)


def test_batch_result_independent_of_composition():
    """Padding envelope (who else is in the batch) must not change an
    instance's result — the wave driver relies on this."""
    systems = _systems((24, 60, 32))
    alone = mw_concurrent_flow_batch([systems[0]], iters=120)[0]
    grouped = mw_concurrent_flow_batch(systems, iters=120)[0]
    assert alone.alpha == grouped.alpha


def test_pathsystembatch_gather_tables_cover_real_hops():
    systems = _systems((24, 40))
    batch = PathSystemBatch.from_systems(systems)
    assert batch.slot_gather is not None and batch.owner_gather is not None
    B, S, D = batch.slot_gather.shape
    P, L = batch.path_edges.shape[1:]
    for i, ps in enumerate(systems):
        real = int((batch.slot_gather[i] < P * L).sum())
        hops = int(ps.path_len.sum())
        assert real == hops  # every real hop appears exactly once


# --------------------------------------------------------------------------- #
# shared-topology fast path
# --------------------------------------------------------------------------- #


def test_shared_batch_matches_sequential():
    (ps,) = _systems((48,))
    rng = np.random.default_rng(0)
    dems = np.stack(
        [
            ps.demands * (0.5 + rng.random(ps.n_commodities).astype(np.float32))
            for _ in range(3)
        ]
    )
    shared = PathSystemBatch.from_shared(ps, dems)
    assert shared.shared and shared.path_edges.ndim == 2
    bat = mw_concurrent_flow_batch(shared, iters=100)
    for d, b in zip(dems, bat):
        s = mw_concurrent_flow(
            dataclasses.replace(ps, demands=d), iters=100, backend="scatter"
        )
        assert s.alpha == b.alpha


def test_shared_batch_rejects_bad_demands():
    (ps,) = _systems((24,))
    with pytest.raises(ValueError, match="shared-batch demands"):
        PathSystemBatch.from_shared(ps, np.ones((2, ps.n_commodities + 1)))


# --------------------------------------------------------------------------- #
# jit-churn fix: padded final window is a masked no-op
# --------------------------------------------------------------------------- #


def test_adaptive_window_padding_is_bit_exact():
    """iters not a multiple of check_every: the padded final window must
    reproduce the single-scan trajectory bit-exactly."""
    (ps,) = _systems((40,))
    full = mw_concurrent_flow(ps, iters=130)
    # never stops early (patience huge), so the windowed run covers the
    # same 130 live steps: 50 + 50 + (30 live + 20 masked no-ops)
    windowed = mw_concurrent_flow(
        ps, iters=130, early_stop=True, check_every=50, rel_tol=0.0,
        patience=10**9,
    )
    assert windowed.iters == 130
    assert windowed.alpha == full.alpha
    np.testing.assert_array_equal(windowed.rates, full.rates)


def test_adaptive_single_compilation_per_solve():
    """The short final window must reuse the full window's compilation."""
    from repro.core import flow

    (ps,) = _systems((32,))
    mw_concurrent_flow(ps, iters=130, early_stop=True, check_every=50,
                       rel_tol=0.0, patience=10**9)
    base = flow._mw_window._cache_size()
    mw_concurrent_flow(ps, iters=130, early_stop=True, check_every=50,
                       rel_tol=0.0, patience=10**9)
    assert flow._mw_window._cache_size() == base


# --------------------------------------------------------------------------- #
# speculative bisection
# --------------------------------------------------------------------------- #


def test_speculative_equals_sequential_monotone():
    for thresh in (0, 1, 137, 999, 1000):
        ok = lambda m: m <= thresh
        ok_b = lambda ms: [ok(m) for m in ms]
        for levels in (1, 2, 3, 5):
            assert speculative_max_feasible(0, 1000, ok_b, levels=levels) == \
                max_feasible(0, 1000, ok)


def test_speculative_equals_sequential_nonmonotone():
    """The wave replays the exact bisection descent, so even a noisy,
    NON-monotone predicate lands on the sequential answer."""
    rng = np.random.default_rng(5)
    table = rng.random(2049) < 0.5
    ok = lambda m: bool(table[m])
    ok_b = lambda ms: [ok(m) for m in ms]
    for lo, hi in [(0, 2048), (100, 1100), (7, 8), (3, 3)]:
        want = max_feasible(lo, hi, ok)
        for levels in (2, 4):
            assert speculative_max_feasible(lo, hi, ok_b, levels=levels) == want


def test_speculative_wave_rounds():
    calls = {"n": 0, "max_cands": 0}

    def ok_b(ms):
        calls["n"] += 1
        calls["max_cands"] = max(calls["max_cands"], len(ms))
        return [m <= 300 for m in ms]

    speculative_max_feasible(0, 1023, ok_b, levels=2)
    assert calls["n"] == 5  # ceil(10 levels / 2)
    assert calls["max_cands"] <= 3  # 2**2 - 1

    with pytest.raises(ValueError, match="levels"):
        speculative_max_feasible(0, 10, ok_b, levels=0)


def test_speculative_bisection_end_to_end_equal():
    """fig1c-style searches (MW probes) agree across drivers."""
    from benchmarks.common import max_servers_at_full_capacity

    kw = dict(seeds=(0,), k=4, method="mw", n_matrices=2)
    seq = max_servers_at_full_capacity(12, 8, 10, 30, **kw)
    wave = max_servers_at_full_capacity(12, 8, 10, 30, wave_levels=2, **kw)
    assert seq == wave


# --------------------------------------------------------------------------- #
# REPRO_LP_PATH_LIMIT (import-time validation) and throughput dispatch
# --------------------------------------------------------------------------- #


def test_lp_path_limit_env_validated_at_import():
    import os
    import pathlib
    import subprocess
    import sys

    root = pathlib.Path(__file__).resolve().parent.parent
    for bad in ("twenty", "-5"):
        env = dict(os.environ, REPRO_LP_PATH_LIMIT=bad)
        env["PYTHONPATH"] = str(root / "src")
        proc = subprocess.run(
            [sys.executable, "-c", "import repro.core.flow"],
            env=env, capture_output=True, text=True, cwd=str(root),
        )
        assert proc.returncode != 0
        assert "REPRO_LP_PATH_LIMIT" in proc.stderr


def test_lp_path_limit_steers_throughput(monkeypatch):
    from repro.core import flow, throughput

    (ps,) = _systems((24,))
    monkeypatch.setattr(flow, "LP_PATH_LIMIT", ps.n_paths)
    assert throughput(ps, iters=40).method == "lp"
    monkeypatch.setattr(flow, "LP_PATH_LIMIT", ps.n_paths - 1)
    assert throughput(ps, iters=40).method.startswith("mw")


# --------------------------------------------------------------------------- #
# MPTCP warm start
# --------------------------------------------------------------------------- #


def test_mptcp_warm_start_plumbing():
    from repro.core import fail_links, mptcp_throughput, update_path_system

    top = jellyfish(40, 10, 6, seed=2)
    comm = random_permutation_traffic(top, seed=1)
    ps = build_path_system(top, comm, k=4)
    base = mptcp_throughput(ps, iters=400)
    assert base.rates is not None and len(base.rates) == ps.n_paths
    delta = update_path_system(ps, top, fail_links(top, n_links=2, seed=3), comm)
    warm = mptcp_throughput(delta, iters=400, warm=base)
    cold = mptcp_throughput(delta, iters=400)
    # warm start changes the transient, not the equilibrium quality
    assert abs(warm.mean_throughput - cold.mean_throughput) < 0.05
    assert warm.rates is not None and len(warm.rates) == delta.n_paths
