"""Throughput solver validation: routing, LP oracle, MW solver, MPTCP fluid."""

import numpy as np
import pytest
from _property import given, settings, st  # hypothesis or deterministic shim

from repro.core import (
    build_path_system,
    fattree,
    jellyfish,
    k_shortest_paths,
    lp_concurrent_flow,
    lp_edge_concurrent_flow,
    mptcp_throughput,
    mw_concurrent_flow,
    random_permutation_traffic,
    throughput,
)


def _system(top, seed=0, k=8):
    comm = random_permutation_traffic(top, seed=seed)
    return build_path_system(top, comm, k=k)


# --------------------------------------------------------------------------- #
# routing
# --------------------------------------------------------------------------- #


def test_ksp_matches_networkx_lengths():
    import networkx as nx

    top = jellyfish(60, 10, 6, seed=5)
    g = nx.Graph(top.edges.tolist())
    pairs = [(0, 30), (1, 59), (10, 20), (5, 6)]
    ours = k_shortest_paths(top, pairs, k=6)
    for (s, t), mine in zip(pairs, ours):
        ref = []
        for i, p in enumerate(nx.shortest_simple_paths(g, s, t)):
            if i >= 6:
                break
            ref.append(len(p) - 1)
        assert sorted(len(p) - 1 for p in mine) == sorted(ref)
        for p in mine:  # simple, adjacent
            assert len(set(p)) == len(p)
            assert all(g.has_edge(a, b) for a, b in zip(p, p[1:]))


def test_path_system_shape_consistency():
    top = jellyfish(30, 8, 5, seed=1)
    ps = _system(top)
    assert ps.path_edges.max() <= 2 * top.n_edges
    assert len(ps.demands) == ps.n_commodities
    assert (ps.path_len >= 1).all()
    # every path's sentinel padding is consistent with its length
    for p in range(0, ps.n_paths, 97):
        row = ps.path_edges[p]
        assert (row[: ps.path_len[p]] < 2 * top.n_edges).all()
        assert (row[ps.path_len[p]:] == 2 * top.n_edges).all()


# --------------------------------------------------------------------------- #
# solvers
# --------------------------------------------------------------------------- #


def test_path_lp_matches_edge_lp_exactly():
    top = jellyfish(16, 6, 4, seed=2)
    comm = random_permutation_traffic(top, seed=3)
    ps = build_path_system(top, comm, k=8, max_slack=4)
    a_path = lp_concurrent_flow(ps).alpha
    a_edge = lp_edge_concurrent_flow(top, comm)
    assert a_path == pytest.approx(a_edge, rel=2e-2)


def test_mw_close_to_lp():
    top = jellyfish(60, 10, 6, seed=4)
    ps = _system(top, seed=5)
    lp = lp_concurrent_flow(ps)
    mw = mw_concurrent_flow(ps, iters=600)
    assert mw.alpha <= lp.alpha * 1.001  # LP is an upper bound
    assert mw.alpha >= lp.alpha * 0.9


def test_fattree_full_bisection_supports_permutation():
    # a full-bisection fat-tree must support any permutation at full rate
    # (k=32 paths: the paper's CPLEX reference is unrestricted routing)
    ft = fattree(6)
    for seed in range(3):
        ps = _system(ft, seed=seed, k=32)
        r = lp_concurrent_flow(ps)
        assert r.alpha >= 1.0 - 1e-6, f"seed={seed} alpha={r.alpha}"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_alpha_monotone_in_capacity(seed):
    top = jellyfish(24, 8, 5, seed=seed)
    ps = _system(top, seed=seed)
    base = lp_concurrent_flow(ps).alpha
    ps.capacities = ps.capacities * 2.0
    doubled = lp_concurrent_flow(ps).alpha
    assert doubled >= base * 1.5  # doubling capacity ~doubles throughput


def test_feasibility_of_solutions():
    top = jellyfish(40, 10, 6, seed=7)
    ps = _system(top, seed=8)
    for solver in (lp_concurrent_flow, lambda p: mw_concurrent_flow(p, 300)):
        res = solver(ps)
        loads = ps.loads(res.rates)
        assert (loads <= ps.capacities * (1 + 1e-4)).all()


def test_throughput_auto_dispatch():
    top = jellyfish(20, 8, 5, seed=9)
    ps = _system(top)
    r = throughput(ps)
    assert 0 < r.alpha


# --------------------------------------------------------------------------- #
# MPTCP fluid model
# --------------------------------------------------------------------------- #


def test_mptcp_feasible_and_reasonable():
    top = jellyfish(50, 10, 6, seed=10)
    ps = _system(top, seed=11)
    res = mptcp_throughput(ps, iters=1500)
    lp = lp_concurrent_flow(ps)
    # feasible: per-flow normalized throughput within [0, 1]
    assert (res.per_flow >= -1e-6).all() and (res.per_flow <= 1 + 1e-6).all()
    # PF mean throughput should be at least the max-min optimum's level
    assert res.mean_throughput >= min(lp.alpha, 1.0) * 0.85
    assert res.jain_index > 0.8


def test_mptcp_on_uncongested_network_saturates():
    # big fat network, few flows: every flow should get ~line rate
    top = jellyfish(40, 13, 12, seed=12)  # 1 server per switch, degree 12
    ps = _system(top, seed=13)
    res = mptcp_throughput(ps, iters=1500)
    assert res.mean_throughput > 0.95
