"""Batched routing engine + kernel-backed flow solver backends.

Covers the PR-1 rebuild: the batched near-shortest-path enumerator against
networkx and the legacy DFS, PathSystem behavior with unrouted (disconnected)
commodities, the per-topology routing cache, and scatter/dense/pallas
congestion-backend parity of the MW solver.
"""

from itertools import islice

import numpy as np
import pytest

from repro.core import (
    Topology,
    build_path_system,
    jellyfish,
    k_shortest_paths,
    lp_concurrent_flow,
    mw_concurrent_flow,
    mptcp_throughput,
    random_permutation_traffic,
    throughput,
)
from repro.core.routing import (
    _k_shortest_paths_dfs,
    _topo_cache,
    _topo_key,
    clear_routing_cache,
)


# --------------------------------------------------------------------------- #
# batched enumerator correctness
# --------------------------------------------------------------------------- #


def test_batched_matches_networkx_simple_paths():
    import networkx as nx

    top = jellyfish(60, 10, 6, seed=5)
    g = nx.Graph(top.edges.tolist())
    pairs = [(0, 30), (1, 59), (10, 20), (5, 6), (42, 3)]
    ours = k_shortest_paths(top, pairs, k=6)
    for (s, t), mine in zip(pairs, ours):
        ref = [len(p) - 1 for p in islice(nx.shortest_simple_paths(g, s, t), 6)]
        assert sorted(len(p) - 1 for p in mine) == sorted(ref)
        for p in mine:  # simple, adjacent, correctly terminated
            assert len(set(p)) == len(p)
            assert p[0] == s and p[-1] == t
            assert all(g.has_edge(a, b) for a, b in zip(p, p[1:]))


def test_batched_matches_legacy_dfs_lengths():
    for seed in range(3):
        top = jellyfish(40, 9, 6, seed=seed)
        rng = np.random.default_rng(seed)
        pairs = [tuple(rng.choice(40, 2, replace=False)) for _ in range(50)]
        batched = k_shortest_paths(top, pairs, k=8)
        dfs = _k_shortest_paths_dfs(top, pairs, k=8)
        for (s, t), pa, pb in zip(pairs, batched, dfs):
            assert sorted(map(len, pa)) == sorted(map(len, pb)), (seed, s, t)


def test_batched_high_slack_sparse_graph():
    """Ring: k=2 needs the full way-around path (slack ~ N - 2*d)."""
    import networkx as nx

    ring = [(i, (i + 1) % 12) for i in range(12)]
    top = Topology.regular(12, 4, 2, ring)
    g = nx.Graph(top.edges.tolist())
    for s, t in [(0, 3), (0, 6), (1, 7)]:
        mine = k_shortest_paths(top, [(s, t)], k=2, max_slack=12)[0]
        ref = [len(p) - 1 for p in islice(nx.shortest_simple_paths(g, s, t), 2)]
        assert sorted(len(p) - 1 for p in mine) == sorted(ref)


def test_reversed_pairs_share_enumeration():
    top = jellyfish(30, 8, 5, seed=2)
    fwd, rev = k_shortest_paths(top, [(3, 17), (17, 3)], k=4)
    assert [p[::-1] for p in fwd] == rev


def test_degenerate_same_node_pair():
    top = jellyfish(20, 8, 5, seed=0)
    assert k_shortest_paths(top, [(4, 4)], k=3) == [[[4]]]


# --------------------------------------------------------------------------- #
# unrouted commodities (disconnected pairs)
# --------------------------------------------------------------------------- #


def _two_island_topology():
    # two K4-ish islands, no bridge; 2 server ports per switch
    edges = [(0, 1), (0, 2), (1, 3), (2, 3), (4, 5), (4, 6), (5, 7), (6, 7)]
    return Topology.regular(8, 5, 3, edges)


def test_path_system_with_unrouted_commodities():
    from repro.core.traffic import Commodities

    top = _two_island_topology()
    comm = Commodities(
        src=np.array([0, 1, 4, 2]),
        dst=np.array([3, 5, 7, 6]),  # 1->5 and 2->6 cross islands: unroutable
        demand=np.ones(4),
        n_flows=4,
    )
    ps = build_path_system(top, comm, k=4)
    assert ps.unrouted.tolist() == [False, True, False, True]
    assert ps.n_commodities == 2
    assert len(ps.demands) == 2
    assert ps.path_owner.max() == 1
    # solvers run on the routable remainder without blowing up
    for solver in (lp_concurrent_flow, lambda p: mw_concurrent_flow(p, 50)):
        res = solver(ps)
        assert np.isfinite(res.alpha) and res.alpha > 0
    res = mptcp_throughput(ps, iters=100)
    assert len(res.per_flow) == 2


def test_path_system_all_unrouted():
    from repro.core.traffic import Commodities

    top = _two_island_topology()
    comm = Commodities(
        src=np.array([0, 1]), dst=np.array([4, 6]), demand=np.ones(2), n_flows=2
    )
    ps = build_path_system(top, comm, k=4)
    assert ps.unrouted.all() and ps.n_paths == 0
    assert mw_concurrent_flow(ps).alpha == 0.0
    assert throughput(ps).alpha == 0.0


# --------------------------------------------------------------------------- #
# per-topology cache
# --------------------------------------------------------------------------- #


def test_routing_cache_reused_across_traffic_matrices():
    top = jellyfish(30, 8, 5, seed=7)
    clear_routing_cache()
    ps1 = build_path_system(top, random_permutation_traffic(top, seed=0), k=4)
    entry = _topo_cache[_topo_key(top)]
    dist_obj = entry["dist"]
    ps2 = build_path_system(top, random_permutation_traffic(top, seed=1), k=4)
    assert _topo_cache[_topo_key(top)]["dist"] is dist_obj  # no recompute
    assert ps1.n_edges == ps2.n_edges
    # cache=False must not touch the shared cache
    clear_routing_cache()
    build_path_system(top, random_permutation_traffic(top, seed=2), k=4,
                      cache=False)
    assert _topo_key(top) not in _topo_cache


def test_cache_distinguishes_topologies():
    a = jellyfish(30, 8, 5, seed=0)
    b = jellyfish(30, 8, 5, seed=1)
    clear_routing_cache()
    pa = build_path_system(a, random_permutation_traffic(a, seed=0), k=4)
    pb = build_path_system(b, random_permutation_traffic(b, seed=0), k=4)
    assert _topo_key(a) != _topo_key(b)
    assert len(_topo_cache) == 2
    assert pa.n_paths > 0 and pb.n_paths > 0


# --------------------------------------------------------------------------- #
# congestion backend parity (scatter vs dense vs pallas kernel)
# --------------------------------------------------------------------------- #


def _parity_system():
    top = jellyfish(40, 10, 6, seed=4)
    comm = random_permutation_traffic(top, seed=5)
    return build_path_system(top, comm, k=8)


def test_fused_kernel_products_match_scatter_math():
    """(B^T r, B w) from the fused pallas kernel == scatter/gather reference.

    This is the lag-free, chaos-free parity check of the primitive itself on
    a real path system's incidence.
    """
    import jax.numpy as jnp

    from repro.core.flow import dense_incidence, make_congestion_fn

    ps = _parity_system()
    pe = jnp.asarray(ps.path_edges)
    rng = np.random.default_rng(0)
    rates = jnp.asarray(rng.uniform(size=ps.n_paths).astype(np.float32))
    prices = jnp.asarray(rng.uniform(size=ps.n_slots).astype(np.float32))
    scatter = make_congestion_fn(pe, ps.n_slots, "scatter")
    pallas = make_congestion_fn(pe, ps.n_slots, "pallas")
    ls, cs = scatter(rates, prices)
    lp_, cp = pallas(rates, prices)
    np.testing.assert_allclose(np.asarray(ls), np.asarray(lp_), atol=1e-5)
    np.testing.assert_allclose(np.asarray(cs), np.asarray(cp), atol=1e-5)
    # loads also agree with the numpy PathSystem oracle
    np.testing.assert_allclose(
        np.asarray(ls), ps.loads(np.asarray(rates)), atol=1e-4
    )


# NOTE: the annealed-softmax MW iteration is chaotic — float accumulation
# order differences between backends amplify with iteration count (1e-7-ish
# at 25 iterations, 1e-4-ish by 400).  The solver-level parity tests therefore
# run a short horizon, where identical math must agree to well under 1e-5;
# the primitive-level test above is exact at any scale.


def test_mw_dense_backend_matches_scatter():
    ps = _parity_system()
    a = mw_concurrent_flow(ps, iters=25, backend="scatter")
    b = mw_concurrent_flow(ps, iters=25, backend="dense")
    assert a.alpha == pytest.approx(b.alpha, abs=1e-5)


def test_mw_pallas_kernel_matches_scatter():
    """The fused congestion_pallas kernel (interpret mode on CPU) drives the
    MW solver to the same alpha as the scatter-add reference."""
    ps = _parity_system()
    a = mw_concurrent_flow(ps, iters=25, backend="scatter")
    b = mw_concurrent_flow(ps, iters=25, backend="pallas")
    assert b.method == "mw-pallas"
    assert a.alpha == pytest.approx(b.alpha, abs=1e-5)
    # both feasible
    for res in (a, b):
        loads = ps.loads(res.rates)
        assert (loads <= ps.capacities * (1 + 1e-4)).all()


def test_mptcp_dense_backend_matches_scatter():
    ps = _parity_system()
    a = mptcp_throughput(ps, iters=200, backend="scatter")
    b = mptcp_throughput(ps, iters=200, backend="dense")
    np.testing.assert_allclose(a.per_flow, b.per_flow, atol=1e-4)


def test_preferred_backend_size_dispatch():
    from repro.kernels import ops

    # tiny instance: dense allowed on CPU; huge instance: scatter
    assert ops.preferred_congestion_backend(100, 200) == "dense"
    assert ops.preferred_congestion_backend(50_000, 80_000) == "scatter"
