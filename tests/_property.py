"""Property-test shim: real hypothesis when installed, deterministic fallback.

Tier-1 must collect and run on a bare container (no ``hypothesis`` wheel
baked in).  Test modules import ``given``/``settings``/``st`` from here; when
hypothesis is available they get the real engine (declared as an optional
dependency in requirements.txt), otherwise a minimal deterministic stand-in
that draws a fixed, seeded set of examples per test — boundary values first,
then pseudo-random draws.  Only the strategy surface this suite uses
(``st.integers``, ``st.booleans``) is implemented; extend as tests grow.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        """Deterministic drop-in for a hypothesis strategy."""

        def __init__(self, boundary, sample):
            self._boundary = list(boundary)  # tried first, in order
            self._sample = sample  # rng -> value

        def example_at(self, i: int, rng: "np.random.Generator"):
            if i < len(self._boundary):
                return self._boundary[i]
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                boundary=[min_value, max_value],
                sample=lambda rng: int(rng.integers(min_value, max_value + 1)),
            )

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(
                boundary=[False, True],
                sample=lambda rng: bool(rng.integers(0, 2)),
            )

    st = _Strategies()

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
        """Decorator recording max_examples on the (given-wrapped) test."""

        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        """Decorator running the test over a deterministic example sweep."""

        def deco(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = getattr(runner, "_prop_max_examples", _DEFAULT_MAX_EXAMPLES)
                # one seeded stream per test: same examples on every run
                # (crc32, not hash(): str hash is randomized per process)
                rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    drawn = {
                        name: strat.example_at(i, rng)
                        for name, strat in strategies.items()
                    }
                    fn(*args, **drawn, **kwargs)

            # hide the strategy params from pytest's fixture resolution
            params = [
                p
                for p in inspect.signature(fn).parameters.values()
                if p.name not in strategies
            ]
            runner.__signature__ = inspect.Signature(params)
            return runner

        return deco
