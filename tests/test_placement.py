"""Placement/cabling (§6) and fabric-aware mesh tests."""

import os
import subprocess
import sys
import pathlib

import numpy as np

from repro.core import fattree, fattree_equipment, jellyfish, plan_cables
from repro.core.placement import localized_jellyfish

SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")


def test_cable_plan_counts_and_lengths():
    top = jellyfish(64, 10, 6, seed=0)
    plan = plan_cables(top)
    assert plan.n_cables == top.n_edges
    assert plan.n_server_cables == top.n_servers
    assert plan.max_length_m > 0
    # switch-cluster layout: all switch-switch cables have ~zero length
    assert plan.mean_length_m < 10.0


def test_jellyfish_fewer_cables_than_fattree():
    """§6.1: ~15% fewer cables at ~1000 servers — because the same server
    pool needs fewer SWITCHES at full capacity (same-equipment comparisons
    trivially tie: every port carries one cable)."""
    k = 16
    eq = fattree_equipment(k)
    ft = fattree(k)
    from benchmarks.common import jellyfish_same_equipment

    jf = jellyfish_same_equipment(int(eq["switches"] * 0.82), k,
                                  eq["servers"], seed=0)
    total_ft = ft.n_edges + ft.n_servers
    total_jf = jf.n_edges + jf.n_servers
    assert jf.n_servers == ft.n_servers
    assert total_jf < total_ft * 0.87  # >= 13% fewer cables


def test_localized_jellyfish_cable_locality():
    top = localized_jellyfish(6, 10, 10, 8, local_links=5, seed=1)
    plan = plan_cables(top)
    assert 0.5 < plan.local_fraction < 0.75


def test_fabric_aware_mesh_subprocess():
    """Pod axis ordered by the ring embedding (needs >=8 fake devices)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
import sys
sys.path.insert(0, %r)
import numpy as np
from repro.fabric import make_fabric
from repro.launch.mesh import make_fabric_aware_mesh

fabric = make_fabric("jellyfish", n_pods=8, degree=4, seed=0)
mesh, order = make_fabric_aware_mesh(fabric, pods=8, per_pod_shape=(2, 2))
assert mesh.shape == {"pod": 8, "data": 2, "model": 2}, mesh.shape
assert sorted(order) == list(range(8))
print("OK")
""" % SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": SRC}, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
